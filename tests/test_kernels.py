"""Pallas kernel sweeps: every kernel vs its pure-jnp oracle (interpret
mode on CPU), across shapes, dtypes, GQA ratios, masks and continuations."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models.ssm import ssd_chunked

rng = np.random.default_rng(0)


def t(*shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, dtype)


FLASH_CASES = [
    # B, Sq, Skv, H, G, D, causal, window, q_offset
    (2, 128, 128, 4, 2, 64, True, 0, 0),
    (1, 100, 100, 8, 2, 64, True, 0, 0),      # non-block-multiple seq
    (2, 64, 256, 4, 4, 32, False, 0, 0),      # cross-attention style
    (1, 128, 128, 4, 1, 64, True, 32, 0),     # sliding window, MQA
    (1, 16, 144, 4, 2, 64, True, 0, 128),     # chunked prefill offset
    (1, 128, 128, 4, 2, 64, True, 100, 0),    # window > block
    (2, 96, 96, 6, 3, 128, True, 0, 0),       # head_dim 128
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_matches_ref(case):
    b, sq, skv, h, g, d, causal, win, qo = case
    q, k, v = t(b, sq, h, d), t(b, skv, g, d), t(b, skv, g, d)
    out = ops.flash_attention(q, k, v, causal=causal, window=win,
                              q_offset=qo, bq=32, bkv=32)
    want = ref.attention_ref(q, k, v, causal=causal, window=win,
                             q_offset=qo)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_bf16():
    q, k, v = (t(1, 64, 4, 64, dtype=jnp.bfloat16) for _ in range(3))
    out = ops.flash_attention(q, k, v, causal=True, bq=32, bkv=32)
    want = ref.attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                             v.astype(jnp.float32), causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want), atol=2e-2, rtol=2e-2)


def test_flash_matches_model_blockwise():
    """Three-way agreement: pallas kernel == model's XLA blockwise path."""
    from repro.configs.base import get_config
    from repro.configs.inputs import reduced_config
    from repro.models.attention import blockwise_attention
    cfg = reduced_config(get_config("qwen1.5-0.5b")).replace(
        attn_q_chunk=16, attn_kv_chunk=32)
    q, k, v = t(2, 64, 4, 16), t(2, 64, 4, 16), t(2, 64, 4, 16)
    xla = blockwise_attention(q, k, v, cfg, causal=True)
    pal = blockwise_attention(q, k, v, cfg.replace(attn_impl="pallas"),
                              causal=True)
    np.testing.assert_allclose(np.asarray(xla), np.asarray(pal),
                               atol=2e-5, rtol=2e-5)


DECODE_CASES = [
    (2, 8, 2, 64, 100),
    (1, 4, 4, 32, 256),
    (3, 16, 2, 128, 77),
    (1, 4, 1, 64, 513),       # MQA, non-multiple cache
]


@pytest.mark.parametrize("case", DECODE_CASES)
def test_decode_attention_matches_ref(case):
    b, h, g, d, w = case
    q, k, v = t(b, 1, h, d), t(b, w, g, d), t(b, w, g, d)
    valid = jnp.asarray(rng.random((b, w)) > 0.3)
    valid = valid.at[:, 0].set(True)          # never fully masked
    out = ops.decode_attention(q, k, v, valid, bkv=32)
    want = ref.decode_attention_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


SSD_CASES = [
    # B, S, H, P, G, N, chunk
    (2, 64, 4, 16, 1, 32, 16),
    (1, 128, 8, 32, 2, 16, 32),
    (2, 96, 4, 64, 1, 128, 48),
    (1, 64, 2, 8, 1, 8, 64),      # single chunk
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_scan_matches_sequential_oracle(case):
    b, s, h, p, g, n, l = case
    x = t(b, s, h, p)
    dt = jnp.abs(t(b, s, h)) * 0.1
    a = -jnp.abs(t(h)) - 0.1
    bb, cc = t(b, s, g, n, scale=0.3), t(b, s, g, n, scale=0.3)
    y1, h1 = ops.ssd_scan(x, dt, a, bb, cc, l)
    y2, h2 = ref.ssd_ref(x, dt, a, bb, cc)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("case", SSD_CASES[:2])
def test_ssd_scan_matches_model_chunked(case):
    """Kernel == the model's independently-written chunked jnp path."""
    b, s, h, p, g, n, l = case
    x = t(b, s, h, p)
    dt = jnp.abs(t(b, s, h)) * 0.1
    a = -jnp.abs(t(h)) - 0.1
    bb, cc = t(b, s, g, n, scale=0.3), t(b, s, g, n, scale=0.3)
    y1, h1 = ops.ssd_scan(x, dt, a, bb, cc, l)
    y2, h2 = ssd_chunked(x, dt, a, bb, cc, l)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=2e-3, rtol=2e-3)


def test_ssd_h0_continuation():
    """Two half-sequence scans chained via h0 == one full scan — the
    property serving prefill-continuation relies on."""
    b, s, h, p, g, n, l = 2, 64, 4, 16, 1, 32, 16
    x = t(b, s, h, p)
    dt = jnp.abs(t(b, s, h)) * 0.1
    a = -jnp.abs(t(h)) - 0.1
    bb, cc = t(b, s, g, n, scale=0.3), t(b, s, g, n, scale=0.3)
    yf, hf = ops.ssd_scan(x, dt, a, bb, cc, l)
    y1, h1 = ops.ssd_scan(x[:, :32], dt[:, :32], a, bb[:, :32],
                          cc[:, :32], l)
    y2, h2 = ops.ssd_scan(x[:, 32:], dt[:, 32:], a, bb[:, 32:],
                          cc[:, 32:], l, h0=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(yf), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(hf),
                               atol=1e-4, rtol=1e-4)


def test_flash_fully_masked_rows_are_finite():
    """Rows whose window excludes every key must not produce NaNs."""
    q, k, v = t(1, 32, 2, 16), t(1, 32, 2, 16), t(1, 32, 2, 16)
    # q_offset far beyond kv length + tiny window: all rows fully masked
    out = ops.flash_attention(q, k, v, causal=True, window=4,
                              q_offset=1000, bq=16, bkv=16)
    assert bool(jnp.all(jnp.isfinite(out)))
