"""Runtime tests: scheduler policy, cost accounting vs the closed-form
model, bit-identical pause/resume, fault recovery, straggler accounting."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.configs.inputs import reduced_config
from repro.core.optimizer import optimal_shutdown
from repro.core.policy import policy_cpc, threshold_policy
from repro.core.tco import cpc_with_shutdowns, make_system, psi
from repro.energy.markets import MarketParams, generate_market
from repro.energy.stream import PriceStream
from repro.runtime.accounting import CostMeter
from repro.runtime.scheduler import (Action, EnergyAwareScheduler,
                                     Partition, SchedulerConfig,
                                     partition_plans)
from repro.runtime.trainer import Trainer, TrainerConfig


@pytest.fixture(scope="module")
def market():
    return generate_market(MarketParams(n_hours=3000, seed=7))


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def test_oracle_threshold_matches_model(market):
    prices = np.asarray(market.prices)
    sched = EnergyAwareScheduler(PriceStream(prices),
                                 SchedulerConfig(psi=2.0, mode="oracle"))
    plan = optimal_shutdown(prices, 2.0)
    assert sched.viable == bool(plan.viable)
    assert sched.p_thresh == pytest.approx(float(plan.p_thresh), rel=1e-5)


def test_scheduler_runs_below_threshold_and_stops_above(market):
    prices = np.asarray(market.prices)
    sched = EnergyAwareScheduler(PriceStream(prices),
                                 SchedulerConfig(psi=2.0, mode="oracle",
                                                 hysteresis=1.0))
    mask = []
    for _ in range(2000):
        a = sched.step()
        mask.append(a in (Action.RUN, Action.RESUME))
    mask = np.asarray(mask)
    want = prices[:2000] <= sched.p_thresh
    # with hysteresis=1.0 the online policy equals the threshold policy
    assert (mask == want).mean() > 0.99


def test_rolling_mode_adapts(market):
    """Rolling mode must track the trailing window: at a Psi where every
    2-week window of the seed-7 series is Eq.-19-viable (Psi=0.25 — see
    ROADMAP; at Psi=2 only ~half the windows are), the threshold is
    always finite, *changes* as the window moves, and is always one of
    the window's own price samples (the PV-set quantile, Eq. 1)."""
    prices = np.asarray(market.prices)
    sched = EnergyAwareScheduler(
        PriceStream(prices, window=24 * 14),
        SchedulerConfig(psi=0.25, mode="rolling", refit_hours=24))
    threshs = []
    for _ in range(24 * 30):
        sched.step()
        threshs.append(sched.p_thresh)
    threshs = np.asarray(threshs)
    assert np.isfinite(threshs).all()
    # the threshold adapts: many distinct values across 30 daily refits
    assert len(np.unique(threshs)) >= 5
    # every threshold is an actual sample of the series (PV quantile)
    for t in np.unique(threshs):
        assert np.isclose(prices, t, rtol=1e-6).any()


def test_rolling_mode_falls_back_to_always_on_when_not_viable(market):
    """At Psi=2 the seed-7 series' final 2-week windows are *not*
    viable (the trailing spike mass is too thin — the generator
    statistic recorded in ROADMAP.md), so rolling mode must end in the
    always-on fallback rather than keep a stale threshold."""
    prices = np.asarray(market.prices)
    sched = EnergyAwareScheduler(
        PriceStream(prices, window=24 * 14),
        SchedulerConfig(psi=2.0, mode="rolling", refit_hours=24))
    for _ in range(24 * 30):
        sched.step()
    assert not sched.viable
    assert sched.p_thresh == np.inf and sched.planned_x == 0.0


def test_overhead_gate_disables_marginal_plans(market):
    prices = np.asarray(market.prices)
    base = EnergyAwareScheduler(PriceStream(prices),
                                SchedulerConfig(psi=2.0))
    k_opt = float(optimal_shutdown(prices, 2.0).k_opt)
    # an overhead big enough to push k(1-o) below Psi+1 must disable it
    overhead = 1.0 - (3.0 / k_opt) + 0.01
    gated = EnergyAwareScheduler(
        PriceStream(prices),
        SchedulerConfig(psi=2.0, restart_overhead_frac=overhead))
    assert base.viable and not gated.viable


def test_partition_plans_lower_psi_more_viable(market):
    prices = np.asarray(market.prices)
    parts = [Partition("efficient", power_mw=0.5, fixed_cost_per_hour=200),
             Partition("power_hog", power_mw=2.0, fixed_cost_per_hour=200)]
    plans = partition_plans(parts, prices)
    assert plans["power_hog"]["psi"] < plans["efficient"]["psi"]
    assert plans["power_hog"]["cpc_reduction"] >= \
        plans["efficient"]["cpc_reduction"]


# ---------------------------------------------------------------------------
# accounting vs closed form
# ---------------------------------------------------------------------------

def test_costmeter_matches_closed_form_threshold_policy(market):
    """Integrating hour-by-hour with a threshold mask must reproduce
    CPC_WS from Eq. (13) (zero restart costs, x from the mask)."""
    prices = np.asarray(market.prices)[:2000]
    sysd = make_system(fixed=160.0 * 2000, power=1.0, period=2000.0)
    plan = optimal_shutdown(prices, float(psi(sysd, prices.mean())))
    thr = float(plan.p_thresh)

    meter = CostMeter(power_mw=1.0, fixed_cost_per_hour=160.0)
    for p in prices:
        meter.tick(1.0, float(p), running=p <= thr)
    mask = threshold_policy(prices, thr)
    want = float(policy_cpc(sysd, prices, mask))
    assert meter.cpc == pytest.approx(want, rel=1e-4)
    # and both agree with the dimensionless closed form
    x = 1.0 - float(mask.mean())
    from repro.core.price_model import price_stats
    st = price_stats(prices, x)
    closed = float(cpc_with_shutdowns(sysd, st.p_avg, st.k, st.x))
    assert meter.cpc == pytest.approx(closed, rel=2e-3)


def test_costmeter_restart_costs_reduce_savings():
    prices = [50.0] * 50 + [500.0] * 5 + [50.0] * 45
    free = CostMeter(power_mw=1.0, fixed_cost_per_hour=100.0)
    costly = CostMeter(power_mw=1.0, fixed_cost_per_hour=100.0)
    for p in prices:
        run = p < 400
        free.tick(1.0, p, running=run)
        costly.tick(1.0, p, running=run)
    costly.restart_event(price=50.0, energy_mwh=2.0, lost_hours=1.0)
    assert costly.cpc > free.cpc


# ---------------------------------------------------------------------------
# trainer: pause/resume, faults, stragglers
# ---------------------------------------------------------------------------

def _mk_trainer(tmp_path, steps=12, scheduler=None, batch_size=2, **kw):
    cfg = reduced_config(get_config("qwen1.5-0.5b"))
    t = Trainer(cfg,
                TrainerConfig(steps=steps, ckpt_dir=str(tmp_path),
                              ckpt_every=4, **kw),
                scheduler=scheduler, batch_size=batch_size, seq_len=16)
    return t


def test_pause_resume_bit_identical(tmp_path, market):
    """A run interrupted by shutdowns must land on exactly the same
    parameters as an uninterrupted run (stateless data + checkpointing)."""
    base = _mk_trainer(tmp_path / "a", steps=10)
    base.run(log_every=0)

    # scheduler that forces a shutdown after every 3rd step
    class Forcing:
        def __init__(self):
            self.i = 0
            self.stream = PriceStream(np.asarray(market.prices))
            self.p_thresh = np.inf
        def step(self, hours=1.0):
            self.i += 1
            self.stream.advance(hours)
            if self.i % 7 == 4:
                return Action.SHUTDOWN
            if self.i % 7 == 5:
                return Action.STAY_DOWN
            if self.i % 7 == 6:
                return Action.RESUME
            return Action.RUN

    intr = _mk_trainer(tmp_path / "b", steps=10, scheduler=Forcing())
    intr.run(log_every=0)
    for a, b in zip(jax.tree.leaves(base.params),
                    jax.tree.leaves(intr.params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_fault_injection_recovers_and_accounts(tmp_path):
    t = _mk_trainer(tmp_path, steps=10, fault_prob_per_step=0.4, seed=3)
    out = t.run(log_every=0)
    assert t.step == 10                      # reached the target anyway
    assert out["lost_steps"] > 0             # and paid for it
    assert np.isfinite(out["final_loss"])


def test_straggler_mitigation_drops_and_renormalises(tmp_path):
    t = _mk_trainer(tmp_path, steps=6, straggler_sigma=1.0,
                    microbatches=4, n_hosts=4, seed=5, batch_size=4)
    out = t.run(log_every=0)
    assert out["dropped_microbatches"] > 0
    assert np.isfinite(out["final_loss"])


def test_energy_aware_run_reduces_energy_cost(tmp_path, market):
    """With hysteresis=1.0 the online policy equals the planned threshold
    policy, so the realised shutdown fraction must match the off-fraction
    of the *covered* price window exactly — not the full-series plan: the
    seed-7 series opens inside a high-price stretch (~72% of the first
    ~100 h sit above the Psi=0.5 threshold vs 37% over the whole series,
    the ROADMAP-noted statistic), so a 30-step run legitimately realises
    x ~ 0.72 while tracking the policy perfectly."""
    prices = np.asarray(market.prices)
    sched = EnergyAwareScheduler(PriceStream(prices),
                                 SchedulerConfig(psi=0.5,  # very viable
                                                 hysteresis=1.0))
    t = _mk_trainer(tmp_path / "ws", steps=30, scheduler=sched)
    out_ws = t.run(log_every=0)
    assert out_ws["restarts"] > 0
    # energy cost must be reduced vs the always-on counterfactual on the
    # same prices (off-hours at positive prices were skipped)
    assert t.meter.energy_cost < t.meter.ao_energy_cost
    # realised x == off-fraction of the threshold policy over the hours
    # actually covered (restart lost-time excluded from the price clock)
    covered = int(round(out_ws["hours"]
                        - out_ws["restarts"] * t.tcfg.restart_time_h))
    want_x = float((prices[:covered] > sched.p_thresh).mean())
    assert out_ws["x_realized"] == pytest.approx(want_x, abs=0.02)
    # and the plan itself is consistent: over the *full* series the
    # threshold policy realises the planned shutdown fraction
    full_x = float((prices > sched.p_thresh).mean())
    assert full_x == pytest.approx(sched.planned_x, abs=0.02)


def test_grad_compress_trains(tmp_path):
    t = _mk_trainer(tmp_path, steps=6, grad_compress=True)
    out = t.run(log_every=0)
    assert np.isfinite(out["final_loss"])
