"""Execution-plan / coupling API tests plus the coupled-sharded psum
acceptance and the fused soft-dispatch VJP pyramid.

Layer 1 — the `repro.execution` pair: `ExecutionPlan` / `Coupling`
constructor invariants, the chunk-under-coupling legality rule, and the
one generic `take_rows` behind `ScenarioGrid`, `LiveGrid` and the
tuner's problem slicing.
Layer 2 — deprecation shims: the pre-redesign `TuneConfig` /
`backtest(chunk_rows=)` spellings warn, forward, and produce identical
results; mixing old and new raises.
Layer 3 — the fused soft-dispatch VJP: values bitwise against
`soft_dispatch_ref`, gradients against the sequential
`soft_dispatch_grad_ref` oracle and native autodiff (f64 under the CI
x64 leg), odd-T padded blocks, and interpret-mode Pallas parity.
Layer 4 — sharded-but-coupled: on >= 2 devices the psum-reduced
coupled objective matches the single program's loss to ULP on the
256-row acceptance grid, its gradient survives an f64 FD check, and a
warm start is carried through the sharded path's row padding instead
of being silently ignored.
"""

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.core.tco import make_system
from repro.dispatch import (DispatchConfig, build_problem, dispatch,
                            segment_keys, segment_rank)
from repro.energy.markets import MarketParams
from repro.execution import (Coupling, ExecutionPlan, take_rows,
                             validate_plan_coupling)
from repro.fleet import PolicySpec, backtest, build_grid
from repro.kernels.ref import soft_dispatch_grad_ref, soft_dispatch_ref
from repro.kernels.soft_dispatch import (soft_dispatch,
                                         soft_dispatch_fused)
from repro.live.grid import build_live_grid
from repro.tune import (TuneConfig, dispatch_coupling_from_grid,
                        init_from_grid, optimize, problem_from_grid,
                        sharded_soft_objective, soft_objective)

F64 = jax.config.jax_enable_x64
N_DEV = len(jax.devices())

_DCFG = DispatchConfig(demand_frac=0.25, migrate_cost=4.0, min_dwell_h=2)


def _grid(n_markets=2, n_policies=4, t=300, off_level=0.3):
    markets = [MarketParams(n_hours=t, seed=s) for s in range(n_markets)]
    sys = make_system(0.6 * t * 80.0, 1.0, float(t))
    pols = [PolicySpec("ao")] + [
        PolicySpec(f"x{i}", x=0.03 * (i + 1), off_level=off_level)
        for i in range(n_policies - 1)]
    return build_grid(markets, [sys], pols)


# ---------------------------------------------------------------------------
# (1) ExecutionPlan / Coupling invariants
# ---------------------------------------------------------------------------

def test_execution_plan_invariants():
    ExecutionPlan()                                   # auto is fine
    ExecutionPlan(mode="chunked", chunk_rows=2)
    ExecutionPlan(mode="sharded", devices=4)
    with pytest.raises(ValueError, match="mode"):
        ExecutionPlan(mode="turbo")
    with pytest.raises(ValueError, match="chunk_rows must be >= 2"):
        ExecutionPlan(chunk_rows=1)
    with pytest.raises(ValueError, match="needs"):
        ExecutionPlan(mode="chunked")
    with pytest.raises(ValueError, match="does not chunk"):
        ExecutionPlan(mode="sharded", chunk_rows=4)
    with pytest.raises(ValueError, match="ULP"):
        ExecutionPlan(mode="sharded", contract="bitwise")


def test_coupling_binds_semantics():
    assert not Coupling().binds
    # reeval alone is post-hoc scoring, not a coupled term
    assert not Coupling(reeval=_DCFG).binds
    assert Coupling(power_cap_mw=10.0).binds
    assert Coupling(dispatch=_DCFG).binds
    assert Coupling(reeval=_DCFG).reeval_config is _DCFG
    assert Coupling(dispatch=_DCFG).reeval_config is _DCFG


def test_chunk_under_coupling_is_constructor_invariant():
    plan = ExecutionPlan(mode="chunked", chunk_rows=4)
    validate_plan_coupling(plan, Coupling())          # unbound: fine
    with pytest.raises(ValueError, match="sharded"):
        validate_plan_coupling(plan, Coupling(dispatch=_DCFG))
    # and the same rule fires at TuneConfig assembly, old or new style
    with pytest.raises(ValueError, match="dispatch_soft"):
        TuneConfig(plan=plan, coupling=Coupling(dispatch=_DCFG))


# ---------------------------------------------------------------------------
# (2) deprecation shims
# ---------------------------------------------------------------------------

def test_tuneconfig_old_spellings_warn_and_forward():
    with pytest.deprecated_call():
        cfg = TuneConfig(chunk_rows=8)
    assert cfg.resolved_plan == ExecutionPlan(
        mode="chunked", chunk_rows=8, contract="bitwise")
    with pytest.deprecated_call():
        cfg = TuneConfig(shard=False)
    assert cfg.resolved_plan.mode == "single"
    with pytest.deprecated_call():
        cfg = TuneConfig(power_cap_mw=5.0, dispatch_soft=_DCFG)
    rc = cfg.resolved_coupling
    assert rc.power_cap_mw == 5.0 and rc.dispatch is _DCFG and rc.binds
    with pytest.deprecated_call():
        cfg = TuneConfig(dispatch=_DCFG)      # reeval-only: not bound
    assert not cfg.resolved_coupling.binds
    assert cfg.resolved_coupling.reeval_config is _DCFG


def test_tuneconfig_new_spellings_do_not_warn():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        cfg = TuneConfig(plan=ExecutionPlan(mode="single"),
                         coupling=Coupling(dispatch=_DCFG))
    assert cfg.resolved_plan.mode == "single"
    assert cfg.resolved_coupling.binds


def test_tuneconfig_mixing_old_and_new_raises():
    with pytest.raises(ValueError, match="not both"):
        TuneConfig(chunk_rows=4, plan=ExecutionPlan())
    with pytest.raises(ValueError, match="not both"):
        TuneConfig(dispatch_soft=_DCFG, coupling=Coupling())


def test_backtest_chunk_rows_deprecated_but_identical():
    grid = _grid()
    ref = backtest(grid, use_pallas=False)
    with pytest.deprecated_call():
        old = backtest(grid, use_pallas=False, chunk_rows=3)
    new = backtest(grid, use_pallas=False,
                   plan=ExecutionPlan(mode="chunked", chunk_rows=3,
                                      contract="bitwise"))
    np.testing.assert_array_equal(np.asarray(old.cpc),
                                  np.asarray(new.cpc))
    np.testing.assert_array_equal(np.asarray(ref.cpc),
                                  np.asarray(new.cpc))
    with pytest.raises(ValueError, match="not both"):
        backtest(grid, chunk_rows=3, plan=ExecutionPlan())
    with pytest.raises(ValueError, match="does not shard"):
        backtest(grid, plan=ExecutionPlan(mode="sharded"))


def test_dispatch_plan_modes():
    prices = np.asarray(
        60 + 25 * np.random.RandomState(0).randn(4, 96), np.float64)
    problem = build_problem(prices, np.full(4, 300.0), np.full(4, 250.0),
                            np.zeros(4), np.ones(4), _DCFG)
    ref = dispatch(problem, use_pallas=False)
    single = dispatch(problem, plan=ExecutionPlan(mode="single"))
    np.testing.assert_array_equal(ref.alloc_mw, single.alloc_mw)
    for mode in ("chunked", "sharded"):
        plan = ExecutionPlan(mode=mode, chunk_rows=2) \
            if mode == "chunked" else ExecutionPlan(mode=mode)
        with pytest.raises(ValueError, match="no row axis"):
            dispatch(problem, plan=plan)


# ---------------------------------------------------------------------------
# (3) the one generic take_rows
# ---------------------------------------------------------------------------

def test_generic_take_rows_matches_manual_slice():
    grid = _grid()
    order = np.asarray([5, 1, 4, 1, 0])
    sub = grid.take_rows(order)
    assert sub.n_rows == 5
    np.testing.assert_array_equal(np.asarray(sub.p_off),
                                  np.asarray(grid.p_off)[order])
    assert sub.prices is grid.prices                  # shared, untouched
    # tuner problem slicing goes through the same implementation
    problem = problem_from_grid(grid)
    probsub = take_rows(problem, order, shared=("prices",))
    np.testing.assert_array_equal(np.asarray(probsub.fixed),
                                  np.asarray(problem.fixed)[order])
    assert probsub.prices is problem.prices


def test_live_grid_take_rows_recurses_into_scenario_grid():
    grid = _grid(n_markets=2, n_policies=2, t=64)
    lgrid = build_live_grid(
        grid, [PolicySpec("ao"), PolicySpec("x3", x=0.03,
                                            off_level=0.3)],
        horizons=(24,), cadences=(2,))
    order = np.arange(lgrid.n_rows)[::-1]
    sub = lgrid.take_rows(order)
    np.testing.assert_array_equal(np.asarray(sub.base_row),
                                  np.asarray(lgrid.base_row)[order])
    np.testing.assert_array_equal(np.asarray(sub.grid.p_off),
                                  np.asarray(lgrid.grid.p_off)[order])
    assert sub.grid.prices is lgrid.grid.prices
    assert sub.horizons == lgrid.horizons             # shared name table


def test_generic_take_rows_refuses_unknown_field_shape():
    grid = _grid()
    bad = dataclasses.replace(grid, period=np.float64(1.0))  # not [B]
    with pytest.raises(TypeError, match="neither a shared field"):
        bad.take_rows(np.asarray([0, 1]))


# ---------------------------------------------------------------------------
# (4) fused soft-dispatch VJP: values + gradients vs oracle and native
# ---------------------------------------------------------------------------

def _dispatch_case(s, t, seed=7):
    r = np.random.default_rng(seed)
    prices = r.normal(80, 40, (s, t)).astype(np.float32)
    power = r.uniform(1.0, 3.0, s).astype(np.float32)
    on = (r.uniform(size=(s, t)) > 0.3).astype(np.float32)
    avail = power[:, None] * (0.2 + 0.8 * on)
    demand = np.full(t, 0.4 * float(avail.sum(axis=0).min()), np.float32)
    keys = segment_keys(prices, 4.0).astype(np.float32)
    order, _ = segment_rank(prices, 4.0)
    return avail, keys, order, demand


FUSED_CASES = [
    # S, T, min_dwell, tau  (odd T exercises the padded final block)
    (3, 64, 0, 5.0),
    (5, 333, 0, 2.0),
    (8, 121, 3, 1.0),
]


@pytest.mark.parametrize("case", FUSED_CASES)
def test_fused_forward_bitwise_vs_ref(case):
    s, t, dwell, tau = case
    avail, keys, order, demand = _dispatch_case(s, t)
    got = np.asarray(soft_dispatch_fused(
        avail, keys, order, demand, tau=tau, min_dwell=dwell,
        use_pallas=False))
    want = np.asarray(soft_dispatch_ref(
        jnp.asarray(avail), jnp.asarray(keys),
        jnp.asarray(order, jnp.int32), jnp.asarray(demand), tau=tau,
        min_dwell=dwell))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("case", FUSED_CASES)
def test_fused_grads_match_oracle_and_native(case):
    s, t, dwell, tau = case
    avail, keys, order, demand = _dispatch_case(s, t)
    g = np.asarray(
        np.random.default_rng(3).normal(size=(s, t)), np.float32)

    def loss_fused(a, k, d, tv):
        return jnp.sum(soft_dispatch_fused(
            a, k, order, d, tau=tv, min_dwell=dwell,
            use_pallas=False) * g)

    def loss_native(a, k, d, tv):
        return jnp.sum(soft_dispatch(
            a, k, order, d, tau=tv, min_dwell=dwell,
            use_pallas=False) * g)

    da, dk, dd, dt = jax.grad(loss_fused, argnums=(0, 1, 2, 3))(
        jnp.asarray(avail), jnp.asarray(keys), jnp.asarray(demand),
        jnp.asarray(tau, jnp.float32))
    oa, ok, od, ot = soft_dispatch_grad_ref(
        avail, keys, order, demand, g, tau=tau, min_dwell=dwell)
    np.testing.assert_array_equal(np.asarray(da), np.asarray(oa))
    np.testing.assert_array_equal(np.asarray(dk), np.asarray(ok))
    np.testing.assert_array_equal(np.asarray(dd), np.asarray(od))
    np.testing.assert_array_equal(np.asarray(dt), np.asarray(ot))
    # and against native autodiff through the scan, in f64 so the
    # comparison is not dominated by f32 round-off
    with enable_x64():
        a64 = jnp.asarray(avail, jnp.float64)
        k64 = jnp.asarray(keys, jnp.float64)
        d64 = jnp.asarray(demand, jnp.float64)
        t64 = jnp.asarray(tau, jnp.float64)
        fa, fk, fd, ft = jax.grad(loss_fused, argnums=(0, 1, 2, 3))(
            a64, k64, d64, t64)
        na, nk, nd, nt = jax.grad(loss_native, argnums=(0, 1, 2, 3))(
            a64, k64, d64, t64)
        for f, n in ((fa, na), (fk, nk), (fd, nd), (ft, nt)):
            np.testing.assert_allclose(np.asarray(f), np.asarray(n),
                                       rtol=1e-9, atol=1e-9)


@pytest.mark.parametrize("case", [(3, 64, 0, 5.0), (5, 77, 2, 2.0)])
def test_fused_pallas_interpret_matches_xla(case):
    """The Pallas fused pair (interpret mode off-TPU) agrees with the
    XLA fused pair — forward bitwise, gradients to f32 round-off —
    including an odd T that pads the final time block."""
    s, t, dwell, tau = case
    avail, keys, order, demand = _dispatch_case(s, t)

    def loss(a, use_pallas):
        return jnp.sum(soft_dispatch_fused(
            a, keys, order, demand, tau=tau, min_dwell=dwell,
            block_t=32, use_pallas=use_pallas, interpret=True))

    np.testing.assert_array_equal(
        np.asarray(soft_dispatch_fused(
            avail, keys, order, demand, tau=tau, min_dwell=dwell,
            block_t=32, use_pallas=True, interpret=True)),
        np.asarray(soft_dispatch_fused(
            avail, keys, order, demand, tau=tau, min_dwell=dwell,
            use_pallas=False)))
    gp = np.asarray(jax.grad(lambda a: loss(a, True))(
        jnp.asarray(avail)))
    gx = np.asarray(jax.grad(lambda a: loss(a, False))(
        jnp.asarray(avail)))
    assert np.all(np.isfinite(gp))
    np.testing.assert_allclose(gp, gx, rtol=2e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# (5) sharded-but-coupled: psum acceptance, FD gradient, warm start
# ---------------------------------------------------------------------------

def _acceptance_grid():
    """The fixed-seed 256-row grid of tests/test_soft_dispatch.py."""
    t = 600
    markets = [MarketParams(n_hours=t, seed=s) for s in range(4)]
    systems = [make_system(float(psi) * t * 1.0 * 80.0, 1.0, float(t))
               for psi in (0.5, 1.0, 2.0, 4.0)]
    xs = (0.01, 0.02, 0.03, 0.05, 0.08, 0.10, 0.12, 0.15,
          0.20, 0.25, 0.30, 0.40)
    policies = [PolicySpec("ao")] + \
        [PolicySpec(f"x{int(x * 100)}", x=x, off_level=0.25)
         for x in xs] + \
        [PolicySpec("x3h", x=0.03, hysteresis=0.9, off_level=0.25),
         PolicySpec("x8h", x=0.08, hysteresis=0.85, off_level=0.25),
         PolicySpec("x15h", x=0.15, hysteresis=0.9, off_level=0.25)]
    return build_grid(markets, systems, policies)


@pytest.mark.skipif(N_DEV < 2, reason="needs >1 device "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count)")
def test_coupled_sharded_objective_ulp_equal_on_acceptance_grid():
    """The tentpole acceptance: the psum-reduced coupled objective
    under shard_map equals the single program's loss to a few ULP on
    the 256-row grid, and its per-row gradient matches to f32
    round-off (the psum transpose is the identity)."""
    grid = _acceptance_grid()
    assert grid.n_rows == 256
    problem = problem_from_grid(grid)
    raw = init_from_grid(grid)
    coupling = dispatch_coupling_from_grid(grid, _DCFG)
    cap = 0.6 * float(np.sum(np.asarray(grid.power)
                             * np.asarray(problem.site_weight)))
    tau = 5.0
    kw = dict(power_cap_mw=cap, dispatch_blend=0.5,
              dispatch_min_dwell=_DCFG.min_dwell_h)

    def single_loss(r):
        loss, _ = soft_objective(r, problem, tau, dispatch=coupling,
                                 reduction="sum", **kw)
        return loss

    n_dev = min(8, N_DEV)
    while grid.n_rows % n_dev:
        n_dev -= 1
    assert n_dev >= 2

    def sharded_loss(r):
        return sharded_soft_objective(r, problem, tau, n_dev=n_dev,
                                      coupling=coupling, **kw)

    single = float(jax.jit(single_loss)(raw))
    sharded = float(jax.jit(sharded_loss)(raw))
    assert abs(sharded - single) <= 4 * np.spacing(np.float32(single)), \
        (single, sharded)

    g1 = jax.grad(single_loss)(raw)
    g2 = jax.grad(sharded_loss)(raw)
    for name in ("raw_off", "raw_gap", "raw_lvl"):
        # f32 round-off only: psum reassociates the per-cell sums, so
        # a few elements move by a couple of ULP of the largest grads
        np.testing.assert_allclose(
            np.asarray(getattr(g2, name)),
            np.asarray(getattr(g1, name)), rtol=1e-4, atol=1e-6,
            err_msg=name)


@pytest.mark.skipif(N_DEV < 2, reason="needs >1 device "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count)")
@pytest.mark.skipif(not F64, reason="FD needs JAX_ENABLE_X64=1")
def test_coupled_sharded_gradient_fd_x64():
    """Central finite differences in f64 confirm the psum-reduced
    gradient end to end (selection softmax, water level, psum'd
    aggregates) on a small coupled fleet."""
    grid = _grid(n_markets=2, n_policies=2, t=96)
    problem = problem_from_grid(grid)
    raw = jax.tree.map(lambda x: jnp.asarray(x, jnp.float64),
                       init_from_grid(grid))
    coupling = dispatch_coupling_from_grid(grid, _DCFG)
    n_dev = 2
    tau = 3.0

    def loss(r):
        return sharded_soft_objective(
            r, problem, tau, n_dev=n_dev, coupling=coupling,
            dispatch_min_dwell=_DCFG.min_dwell_h, fused=False)

    g = jax.grad(loss)(raw)
    eps = 1e-5
    r = np.random.default_rng(11)
    for name in ("raw_off", "raw_gap", "raw_lvl"):
        vec = np.asarray(getattr(raw, name), np.float64)
        for b in r.choice(vec.shape[0], size=2, replace=False):
            e = np.zeros_like(vec)
            e[b] = eps
            hi = loss(raw._replace(**{name: jnp.asarray(vec + e)}))
            lo = loss(raw._replace(**{name: jnp.asarray(vec - e)}))
            fd = (float(hi) - float(lo)) / (2 * eps)
            ad = float(np.asarray(getattr(g, name))[b])
            assert abs(fd - ad) <= 1e-4 * max(1.0, abs(fd)), \
                (name, b, fd, ad)


@pytest.mark.skipif(N_DEV < 2, reason="needs >1 device "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count)")
def test_sharded_plan_tunes_coupled_and_pads_warm_start():
    """An explicit sharded plan runs a coupled tuning loop (the old
    path raised), agrees with the single program per row, and carries a
    warm start through the row padding the shard widths force — the
    silent warm-start drop this PR fixes."""
    grid = _grid(n_markets=2, n_policies=3, t=96)   # 6 rows: pads on 4
    coup = Coupling(dispatch=_DCFG)
    steps = 6
    single = optimize(grid, TuneConfig(
        steps=steps, plan=ExecutionPlan(mode="single"), coupling=coup))
    sharded = optimize(grid, TuneConfig(
        steps=steps, plan=ExecutionPlan(mode="sharded"), coupling=coup))
    for name in ("raw_off", "raw_gap", "raw_lvl"):
        np.testing.assert_allclose(
            np.asarray(getattr(sharded.raw, name)),
            np.asarray(getattr(single.raw, name)), rtol=5e-5,
            atol=5e-5, err_msg=name)

    # warm start actually steers the sharded run: restarting from the
    # tuned params with a tiny budget stays near them, while the cold
    # run from the swept seed lands elsewhere
    warm = optimize(grid, TuneConfig(
        steps=2, plan=ExecutionPlan(mode="sharded"), coupling=coup),
        warm_start=single)
    cold = optimize(grid, TuneConfig(
        steps=2, plan=ExecutionPlan(mode="sharded"), coupling=coup))
    drift_warm = float(np.max(np.abs(np.asarray(warm.raw.raw_off)
                                     - np.asarray(single.raw.raw_off))))
    drift_cold = float(np.max(np.abs(np.asarray(cold.raw.raw_off)
                                     - np.asarray(single.raw.raw_off))))
    assert drift_warm < drift_cold, (drift_warm, drift_cold)
    assert drift_warm < 2.1 * 0.5 * steps  # bounded by lr per step
