"""Fused checkpointed soft-scan VJP tests: value and gradient agreement
with native autodiff (f32 here; the CI x64 leg reruns this file under
JAX_ENABLE_X64 where the tolerances tighten to ~1e-10), parity of both
custom backwards (blocked XLA and Pallas-interpret) against the
sequential gradient oracle `soft_scan_grad_ref`, odd-T / padded block
shapes, and the scaled-out `optimize` paths (chunked; shard_map when
the host exposes more than one device) reproducing the single-program
result bit for bit."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.tco import make_system
from repro.energy.markets import MarketParams
from repro.fleet import PolicySpec, build_grid
from repro.kernels.ref import soft_scan_grad_ref
from repro.kernels.soft_scan import soft_state
from repro.kernels.soft_scan_vjp import soft_state_fused
from repro.tune import TuneConfig, optimize

rng = np.random.default_rng(29)

F64 = jax.config.jax_enable_x64
# native autodiff and the fused backward differ only in how the time
# reduction is associated; in f64 that is ~1e-12 relative, in f32 a few
# hundred ULP on T ~ 10^3 sums
RTOL = 1e-10 if F64 else 1e-5


def _case(b, t):
    p = jnp.asarray(rng.normal(80, 40, (b, t)))
    p_off = jnp.asarray(rng.uniform(60, 140, b))
    p_on = p_off - jnp.asarray(rng.uniform(0.5, 30, b))
    w = jnp.asarray(rng.normal(0, 1, (b, t)))
    return p, p_on, p_off, w


def _grads(fn, p, p_on, p_off, tau, w):
    def loss(p_, on_, off_, tau_):
        return jnp.sum(w * fn(p_, on_, off_, tau=tau_))
    return jax.grad(loss, argnums=(0, 1, 2, 3))(
        p, p_on, p_off, jnp.asarray(tau, p.dtype))


def _assert_close(got, want, *, rtol, name):
    got, want = np.asarray(got), np.asarray(want)
    atol = rtol * max(1.0, float(np.abs(want).max()))
    np.testing.assert_allclose(got, want, rtol=rtol, atol=atol,
                               err_msg=name)


# ---------------------------------------------------------------------------
# (a) values and gradients vs native autodiff, both implementations
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["xla", "pallas_interpret"])
@pytest.mark.parametrize("tau", [20.0, 2.0, 0.3])
def test_fused_matches_native_values(use_pallas, tau):
    p, p_on, p_off, _ = _case(6, 333)
    want = soft_state(p, p_on, p_off, tau=tau)
    got = soft_state_fused(p, p_on, p_off, tau=tau, block_t=64,
                           use_pallas=use_pallas)
    # the pallas kernels compute in f32 regardless of x64
    tol = 1e-5 if use_pallas else RTOL
    _assert_close(got, want, rtol=max(tol, 1e-12), name="s")


@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["xla", "pallas_interpret"])
def test_fused_gradients_match_native_autodiff(use_pallas):
    """custom_vjp vs jax.grad through the associative scan, every
    cotangent (prices, p_on, p_off, tau)."""
    p, p_on, p_off, w = _case(5, 301)
    tau = 4.0
    gn = _grads(soft_state, p, p_on, p_off, tau, w)
    gf = _grads(lambda *a, **k: soft_state_fused(
        *a, block_t=64, use_pallas=use_pallas, **k), p, p_on, p_off,
        tau, w)
    tol = 1e-5 if use_pallas else RTOL
    for name, a, b in zip(("d_prices", "d_p_on", "d_p_off", "d_tau"),
                          gn, gf):
        _assert_close(b, a, rtol=max(tol, 1e-12), name=name)


# ---------------------------------------------------------------------------
# (b) both backwards vs the sequential gradient oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["xla", "pallas_interpret"])
def test_fused_bwd_matches_grad_ref_oracle(use_pallas):
    p, p_on, p_off, w = _case(4, 173)
    tau = 3.0
    want = soft_scan_grad_ref(p, p_on, p_off, w, tau=tau)

    def loss(p_, on_, off_, tau_):
        return jnp.sum(w * soft_state_fused(
            p_, on_, off_, tau=tau_, block_t=32, use_pallas=use_pallas))

    got = jax.grad(loss, argnums=(0, 1, 2, 3))(
        p, p_on, p_off, jnp.asarray(tau, p.dtype))
    tol = 1e-5 if use_pallas else RTOL
    for name, a, b in zip(("d_prices", "d_p_on", "d_p_off", "d_tau"),
                          got, want):
        _assert_close(a, b, rtol=max(tol, 1e-12), name=name)


def test_grad_ref_oracle_matches_native_autodiff():
    """The oracle itself is pinned to ground truth."""
    p, p_on, p_off, w = _case(3, 97)
    tau = 6.0
    want = jax.grad(
        lambda *a: jnp.sum(w * soft_state(*a[:3], tau=a[3])),
        argnums=(0, 1, 2, 3))(p, p_on, p_off, jnp.asarray(tau, p.dtype))
    got = soft_scan_grad_ref(p, p_on, p_off, w, tau=tau)
    for name, a, b in zip(("d_prices", "d_p_on", "d_p_off", "d_tau"),
                          got, want):
        _assert_close(a, b, rtol=max(RTOL, 1e-12), name=name)


# ---------------------------------------------------------------------------
# (c) padding / odd shapes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["xla", "pallas_interpret"])
@pytest.mark.parametrize("b,t,bt", [
    (3, 40, 64),     # T smaller than one block
    (5, 333, 64),    # odd T, partial last block
    (2, 513, 256),   # one sample past a block boundary
    (1, 7, 4),       # tiny everything, non-128 block
])
def test_fused_padded_and_odd_shapes(use_pallas, b, t, bt):
    p, p_on, p_off, w = _case(b, t)
    tau = 2.0
    want_s = soft_state(p, p_on, p_off, tau=tau)
    got_s = soft_state_fused(p, p_on, p_off, tau=tau, block_t=bt,
                             use_pallas=use_pallas)
    _assert_close(got_s, want_s, rtol=1e-5, name="s")

    def loss(fn):
        return lambda on_, off_: jnp.sum(w * fn(p, on_, off_))

    gn = jax.grad(loss(lambda p_, a_, b_: soft_state(
        p_, a_, b_, tau=tau)), argnums=(0, 1))(p_on, p_off)
    gf = jax.grad(loss(lambda p_, a_, b_: soft_state_fused(
        p_, a_, b_, tau=tau, block_t=bt, use_pallas=use_pallas)),
        argnums=(0, 1))(p_on, p_off)
    for name, a, b_ in zip(("d_p_on", "d_p_off"), gn, gf):
        _assert_close(b_, a, rtol=1e-5, name=name)


# ---------------------------------------------------------------------------
# (d) scaled-out optimize paths are bit-consistent
# ---------------------------------------------------------------------------

def _tiny_grid(t=300):
    markets = [MarketParams(n_hours=t, seed=3), MarketParams(n_hours=t,
                                                             seed=4)]
    systems = [make_system(0.8 * t * 80.0, 1.0, float(t))]
    policies = [PolicySpec("ao"), PolicySpec("x5", x=0.05),
                PolicySpec("x15", x=0.15), PolicySpec("x30", x=0.3)]
    return build_grid(markets, systems, policies)     # 8 rows


def _assert_bit_identical(a, b):
    for name in ("cpc", "cpc_tuned"):
        assert np.array_equal(np.asarray(getattr(a, name)),
                              np.asarray(getattr(b, name))), name
    for name in ("raw_off", "raw_gap", "raw_lvl"):
        assert np.array_equal(np.asarray(getattr(a.raw, name)),
                              np.asarray(getattr(b.raw, name))), name
    for name in ("p_on", "p_off", "off_level"):
        assert np.array_equal(np.asarray(getattr(a.params, name)),
                              np.asarray(getattr(b.params, name))), name


def test_chunked_optimize_bit_identical():
    """Row chunking (including a padded final chunk) reproduces the
    unchunked trajectory and selection exactly."""
    grid = _tiny_grid()
    single = optimize(grid, TuneConfig(steps=25, shard=False))
    chunked = optimize(grid, TuneConfig(steps=25, shard=False,
                                        chunk_rows=3))
    _assert_bit_identical(single, chunked)


def test_chunked_optimize_bit_identical_8192_rows():
    """The memory-lean path at scale: a 8192-row grid tuned in 2048-row
    chunks is bit-identical to the one-shot program (per-row gradients
    are batch-independent, every chunk compiles to the same shape)."""
    t = 168
    markets = [MarketParams(n_hours=t, seed=s) for s in (0, 1)]
    systems = [make_system(float(psi) * t * 80.0, 1.0, float(t))
               for psi in np.geomspace(0.5, 4.0, 8)]
    policies = [PolicySpec(f"x{i}", x=float(x))
                for i, x in enumerate(np.linspace(0.005, 0.6, 512))]
    grid = build_grid(markets, systems, policies)
    assert grid.n_rows == 8192
    cfg = TuneConfig(steps=6, shard=False)
    single = optimize(grid, cfg)
    chunked = optimize(grid, cfg._replace(chunk_rows=2048))
    _assert_bit_identical(single, chunked)
    assert np.all(single.cpc <= single.cpc_swept_best * (1.0 + 1e-6))


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >1 device "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count)")
def test_sharded_optimize_matches_single_device():
    """shard_map over the row axis reproduces the single-device result.

    The math is batch-independent, but XLA:CPU emits slightly different
    (vector-width-dependent) code for different shard widths, so unlike
    the equal-shape chunked path the comparison is ULP-tight rather
    than bitwise: raw parameters within ~1e-5 relative after 25 Adam
    steps, hard-re-evaluated CPC within float tolerance."""
    grid = _tiny_grid()
    single = optimize(grid, TuneConfig(steps=25, shard=False))
    sharded = optimize(grid, TuneConfig(steps=25, shard=True))
    for name in ("raw_off", "raw_gap", "raw_lvl"):
        a = np.asarray(getattr(single.raw, name))
        b = np.asarray(getattr(sharded.raw, name))
        np.testing.assert_allclose(b, a, rtol=1e-5,
                                   atol=1e-5 * max(1.0, np.abs(a).max()),
                                   err_msg=name)
    np.testing.assert_allclose(sharded.cpc, single.cpc, rtol=1e-5)
    np.testing.assert_allclose(sharded.cpc_tuned, single.cpc_tuned,
                               rtol=1e-5)
    assert np.allclose(single.history["loss"], sharded.history["loss"],
                       rtol=1e-5)
