"""Workload-coupled demand tests: the hour-by-hour conservation
invariant of the work ledger (exact in f64 on integer-valued work),
agreement of all three ledger implementations (`queue_scan`, the
sequential `queue_scan_ref` oracle, the pure-numpy `replay_ledger`),
soft-ledger convergence as tau -> 0 and FD gradients of the SLO-aware
objective (tight under the CI x64 leg), the zero-workload bit-identity
contract of `workload_backtest` on the 256-row acceptance grid
(telemetry on and off, plus the `_force_coupled` fleet-half no-op),
seeded determinism of the CPC quantiles, SLO-aware tuning's
selected-cost bound, the live replay, demand-surge coupling, and
derandomized property-based checks over random workload specs x price
series (tests/_hypothesis_compat.py)."""

import os
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core.tco import make_system
from repro.dispatch import DispatchConfig, resolve_demand
from repro.energy.markets import MarketParams
from repro.faults import FaultEvent, FaultTrace
from repro.fleet import PolicySpec, backtest, build_grid, summarize
from repro.kernels.queue_scan import (QUEUE_MWH_SCALE, queue_scan,
                                      smoothclip, workload_fleet_scan)
from repro.kernels.ref import fleet_scan_ref, queue_scan_ref
from repro.live import live_fleet_dispatch
from repro.obs.report import load_events, render_digest
from repro.obs.schema import validate
from repro.tune import TuneConfig, optimize
from repro.tune.objective import (init_from_grid, problem_from_grid,
                                  soft_objective)
from repro.tune.optimizer import cell_best_rows
from repro.workload import (Workload, ledger_cost, realized_cost,
                            replay_ledger, workload_backtest)

from tests._hypothesis_compat import (HAVE_HYPOTHESIS, given, settings,
                                      st)

F64 = jax.config.jax_enable_x64

GOLDEN = Path(__file__).resolve().parent / "golden" / "workload_digest.md"

rng = np.random.default_rng(11)


def _grid(n_markets=2, t=400, workload=None):
    markets = [MarketParams(n_hours=t, seed=s) for s in range(n_markets)]
    sys = make_system(0.5 * t * 80.0, 1.0, float(t))
    pols = [PolicySpec("ao"), PolicySpec("x10", x=0.10, off_level=0.3),
            PolicySpec("x30", x=0.30, off_level=0.3)]
    return build_grid(markets, [sys], pols, workload=workload)


def _acceptance_grid():
    """The fixed-seed 256-row grid shared with tests/test_tune.py."""
    t = 600
    markets = [MarketParams(n_hours=t, seed=s) for s in range(4)]
    systems = [make_system(float(psi) * t * 1.0 * 80.0, 1.0, float(t))
               for psi in (0.5, 1.0, 2.0, 4.0)]
    xs = (0.01, 0.02, 0.03, 0.05, 0.08, 0.10, 0.12, 0.15,
          0.20, 0.25, 0.30, 0.40)
    policies = [PolicySpec("ao")] + \
        [PolicySpec(f"x{int(x * 100)}", x=x, off_level=0.25)
         for x in xs] + \
        [PolicySpec("x3h", x=0.03, hysteresis=0.9, off_level=0.25),
         PolicySpec("x8h", x=0.08, hysteresis=0.85, off_level=0.25),
         PolicySpec("x15h", x=0.15, hysteresis=0.9, off_level=0.25)]
    return build_grid(markets, systems, policies)


def _assert_reports_equal(a, b):
    for f in a._fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)),
                                      err_msg=f)


def _int_case(r=3, t=40, seed=0, hi=6):
    """Integer-valued f64 arrivals/capacity: every ledger sum is exact
    in double precision (< 2^53), so conservation is testable with
    ``==`` instead of allclose."""
    g = np.random.default_rng(seed)
    a = g.integers(0, hi, (r, t)).astype(np.float64)
    c = g.integers(0, hi, (r, t)).astype(np.float64)
    return a, c


# ---------------------------------------------------------------------------
# Workload spec: arrival model and MW conversion
# ---------------------------------------------------------------------------

def test_workload_validation():
    with pytest.raises(ValueError):
        Workload(base_rps=-1.0)
    with pytest.raises(ValueError):
        Workload(n_draws=0)
    with pytest.raises(ValueError):
        Workload(deadline_h=-1)
    with pytest.raises(ValueError):
        Workload(tokens_per_engine_hour=0.0)


def test_arrival_rate_diurnal_peak_and_mult():
    wl = Workload(base_rps=2.0, diurnal_amp=0.6, peak_hour=17.0)
    lam = wl.arrival_rate(48)
    assert lam.shape == (48,)
    assert (lam >= 0.0).all()
    assert int(np.argmax(lam[:24])) == 17
    mult = np.ones(48)
    mult[10] = 2.5
    lam2 = wl.arrival_rate(48, mult)
    np.testing.assert_allclose(lam2[10], 2.5 * lam[10])
    np.testing.assert_allclose(np.delete(lam2, 10), np.delete(lam, 10))


def test_sample_requests_seeded_and_shaped():
    wl = Workload(n_draws=5, seed=3)
    a = wl.sample_requests(72)
    b = wl.sample_requests(72)
    assert a.shape == (5, 72)
    np.testing.assert_array_equal(a, b)
    c = Workload(n_draws=5, seed=4).sample_requests(72)
    assert not np.array_equal(a, c)
    # overdispersed: across-draw variance well above Poisson's lam
    lam = wl.arrival_rate(72)
    assert a.var(axis=0).mean() > 1.5 * lam.mean()


def test_mean_demand_is_rate_conversion():
    wl = Workload()
    t = 30
    np.testing.assert_allclose(
        wl.mean_demand_mw(t), wl.requests_to_mw(wl.arrival_rate(t)))
    # default spec lands near one fleet row's 1 MW rating
    assert 0.3 < float(np.mean(wl.mean_demand_mw(168))) < 3.0


def test_from_serving_and_from_roofline():
    from repro.serving.engine import ServeConfig
    scfg = ServeConfig()
    wl = Workload.from_serving(scfg)
    assert wl.tokens_per_engine_hour == pytest.approx(
        scfg.slots / scfg.hours_per_tick)
    assert wl.engine_power_mw == pytest.approx(float(scfg.power_mw))
    from repro.configs.base import get_config
    wl2 = Workload.from_roofline(get_config("qwen1.5-0.5b"))
    assert wl2.tokens_per_engine_hour > 0.0
    assert np.isfinite(wl2.mw_per_request_hour)


# ---------------------------------------------------------------------------
# the hard ledger: three implementations, one answer
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("deadline,bound", [(0, 5.0), (2, 3.0),
                                            (4, 100.0), (3, 0.0)])
def test_ledger_implementations_agree_exactly(deadline, bound):
    a, c = _int_case(seed=deadline)
    out, hourly = queue_scan(a, c, deadline=deadline, bound=bound,
                             hourly=True)
    s_ref, d_ref, b_ref, q_ref = queue_scan_ref(a, c, deadline=deadline,
                                                bound=bound)
    np.testing.assert_array_equal(np.asarray(hourly.served), s_ref)
    np.testing.assert_array_equal(np.asarray(hourly.dropped), d_ref)
    np.testing.assert_array_equal(np.asarray(hourly.backlog), b_ref)
    np.testing.assert_array_equal(np.asarray(out.q_final), q_ref)
    for r in range(a.shape[0]):
        rep = replay_ledger(a[r], c[r], deadline=deadline, bound=bound)
        np.testing.assert_array_equal(rep.served,
                                      np.asarray(hourly.served)[r])
        np.testing.assert_array_equal(rep.dropped,
                                      np.asarray(hourly.dropped)[r])
        np.testing.assert_array_equal(rep.backlog,
                                      np.asarray(hourly.backlog)[r])


def test_conservation_exact_per_hour_per_row():
    """arrivals + carried-in backlog == served + dropped + carried-out,
    exactly, each hour, each row (integer-valued f64 work)."""
    a, c = _int_case(r=4, t=60, seed=9)
    _, h = queue_scan(a, c, deadline=3, bound=4.0, hourly=True)
    srv, drp, bkl = (np.asarray(v) for v in h)
    carried_in = np.concatenate([np.zeros((4, 1)), bkl[:, :-1]], axis=1)
    np.testing.assert_array_equal(a + carried_in, srv + drp + bkl)


def test_deadline_aging_drops_at_expiry():
    """With zero capacity and a huge bound, every MWh drops exactly
    deadline + 1 hours after arriving."""
    t, d = 10, 3
    a = np.zeros(t)
    a[0] = 5.0
    rep = replay_ledger(a, np.zeros(t), deadline=d, bound=1e9)
    want = np.zeros(t)
    want[d] = 5.0     # arrives hour 0, survives d queue hours, expires
    np.testing.assert_array_equal(rep.dropped, want)
    assert rep.backlog[:d].tolist() == [5.0] * d


def test_queue_bound_drops_overflow_immediately():
    rep = replay_ledger(np.array([10.0, 0.0]), np.zeros(2), deadline=4,
                        bound=3.0)
    assert rep.backlog[0] == 3.0
    assert rep.dropped[0] == 7.0


def test_ledger_cost_rates():
    a, c = _int_case(r=1, t=30, seed=2)
    rep = replay_ledger(a[0], c[0], deadline=2, bound=5.0)
    cost = ledger_cost(rep, slo_penalty_eur_mwh=40.0, voll_eur_mwh=3000.0)
    assert cost["defer_cost"] == pytest.approx(40.0 * rep.backlog.sum())
    assert cost["drop_cost"] == pytest.approx(3000.0 * rep.dropped.sum())
    assert cost["served_mwh"] == pytest.approx(rep.served.sum())


# ---------------------------------------------------------------------------
# the soft ledger: convergence and gradients
# ---------------------------------------------------------------------------

def test_smoothclip_limits():
    z = jnp.linspace(-3.0, 8.0, 50)
    np.testing.assert_array_equal(np.asarray(smoothclip(z, 0.0, 0.1)),
                                  0.0)
    soft = np.asarray(smoothclip(z, 5.0, 1e-4))
    np.testing.assert_allclose(soft, np.clip(np.asarray(z), 0.0, 5.0),
                               atol=1e-3)
    mid = np.asarray(smoothclip(z, 5.0, 1.0))
    assert (mid > 0.0).all() and (mid < 5.0).all()
    assert (np.diff(mid) >= 0.0).all()


def test_soft_queue_converges_to_hard():
    a, c = _int_case(r=2, t=50, seed=5)
    hard = queue_scan(a, c, deadline=2, bound=3.0)
    errs = []
    for tau in (1.0, 1e-1, 1e-2, 1e-4):
        soft = queue_scan(a, c, deadline=2, bound=3.0, tau=tau)
        errs.append(max(float(np.abs(np.asarray(soft.served)
                                     - np.asarray(hard.served)).max()),
                        float(np.abs(np.asarray(soft.dropped)
                                     - np.asarray(hard.dropped)).max())))
    assert errs[-1] < 1e-2
    assert errs[-1] < errs[0]


def test_soft_queue_fd_gradients():
    """Central-difference check of d(soft SLO cost)/d(capacity) — the
    gradient the tuner descends. Tight under the CI x64 leg."""
    a, c = _int_case(r=1, t=20, seed=7)
    a, c = jnp.asarray(a[0]), jnp.asarray(c[0] + 0.5)
    tau = 0.3

    def cost(cap):
        out = queue_scan(a, cap, deadline=2, bound=3.0, tau=tau)
        return 4.0 * out.backlog + 30.0 * out.dropped

    g = np.asarray(jax.grad(cost)(c))
    assert np.isfinite(g).all() and np.abs(g).max() > 0.0
    h = 1e-5 if F64 else 3e-2
    rtol = 1e-6 if F64 else 1e-1
    checked = 0
    for i in (0, 5, 13):
        e = jnp.zeros_like(c).at[i].set(h)
        fd = float((cost(c + e) - cost(c - e)) / (2 * h))
        if not F64 and abs(fd) < 0.2:
            continue           # below f32 central-difference resolution
        checked += 1
        np.testing.assert_allclose(g[i], fd, rtol=rtol,
                                   atol=rtol * max(1.0, abs(fd)),
                                   err_msg=f"cap[{i}]")
    assert checked >= 1


def test_slo_objective_fd_gradients():
    """FD check of the full SLO-aware soft objective w.r.t. the raw
    threshold parameters on a tiny grid."""
    grid = _grid(n_markets=1, t=120)
    problem = problem_from_grid(grid)
    raw = init_from_grid(grid)
    wl = Workload()
    dem = jnp.asarray(wl.mean_demand_mw(120))
    tau = 5.0

    def loss(off):
        return soft_objective(raw._replace(raw_off=off), problem, tau,
                              workload=wl, workload_demand=dem,
                              reduction="sum")[0]

    off = jnp.asarray(raw.raw_off)
    g = np.asarray(jax.grad(loss)(off))
    assert np.isfinite(g).all()
    # the objective pipeline computes in f32 (grid dtype) even under
    # x64, so the FD step/tolerance are f32-scaled in both modes
    h, rtol = 0.1, 0.15
    checked = 0
    for i in range(off.shape[0]):
        e = jnp.zeros_like(off).at[i].set(h)
        fd = float((loss(off + e) - loss(off - e)) / (2 * h))
        if abs(fd) < 1e-4:
            continue           # below the f32 central-difference floor
        checked += 1
        np.testing.assert_allclose(g[i], fd, rtol=rtol,
                                   atol=rtol * abs(fd),
                                   err_msg=f"raw_off[{i}]")
    assert checked >= 1


def test_workload_term_off_is_inert():
    """workload=None leaves the soft objective's loss and gradients
    exactly as before (the aux key is zeros)."""
    grid = _grid(n_markets=1, t=100)
    problem = problem_from_grid(grid)
    raw = init_from_grid(grid)
    l0, aux0 = soft_objective(raw, problem, 5.0, reduction="sum")
    np.testing.assert_array_equal(np.asarray(aux0["workload"]), 0.0)
    wl = Workload()
    l1, aux1 = soft_objective(
        raw, problem, 5.0, workload=wl,
        workload_demand=jnp.asarray(wl.mean_demand_mw(100)),
        reduction="sum")
    assert float(l1) > float(l0)
    assert (np.asarray(aux1["workload"]) > 0.0).all()


# ---------------------------------------------------------------------------
# workload_backtest: zero-workload bit-identity + coupled results
# ---------------------------------------------------------------------------

def test_zero_workload_short_circuits():
    grid = _grid()
    res = workload_backtest(grid)
    assert res.workload is None
    _assert_reports_equal(backtest(grid, use_pallas=False), res.report)


def test_zero_workload_bit_identical_on_acceptance_grid(tmp_path):
    """The acceptance contract: on the 256-row grid the coupled
    program's FleetReport is bitwise the plain backtest — the ledger
    rides the carry without feeding back — telemetry off AND on."""
    grid = _acceptance_grid()
    assert grid.n_rows == 256
    ref = backtest(grid, use_pallas=False)
    forced = workload_backtest(grid, _force_coupled=True)
    assert forced.workload is not None
    _assert_reports_equal(ref, forced.report)
    obs.enable(tmp_path / "run", run_id="zw")
    try:
        traced = workload_backtest(grid, _force_coupled=True)
    finally:
        obs.disable()
    _assert_reports_equal(ref, traced.report)
    events = load_events(tmp_path / "run")
    kinds = {e["kind"] for e in events}
    assert "workload.hourly" in kinds and "workload.result" in kinds
    assert not any(validate(e) for e in events)


def test_workload_fleet_scan_fleet_half_is_fleet_scan_ref():
    grid = _grid(t=300)
    p_rows = jnp.asarray(grid.prices)[grid.market_idx]
    ref = fleet_scan_ref(p_rows, grid.p_on, grid.p_off, grid.off_level,
                         grid.idle_frac)
    dem = jnp.asarray(Workload(n_draws=4).sample_demand_mw(300),
                      jnp.float32)
    out = workload_fleet_scan(p_rows, grid.p_on, grid.p_off,
                              grid.off_level, grid.idle_frac,
                              grid.power * grid.period / 300.0, dem,
                              grid.period / 300.0, deadline=4, bound=4.0)
    _assert_reports_equal(ref, out.fleet)


def test_workload_result_sane_and_deterministic():
    wl = Workload(n_draws=6, seed=2)
    grid = _grid(workload=wl)
    a = workload_backtest(grid).workload
    b = workload_backtest(grid).workload
    for f in ("served_mwh", "dropped_mwh", "cpc_p10", "cpc_p50",
              "cpc_p90"):
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), f)
    assert a.n_draws == 6
    srv, drp, arr = (np.asarray(v) for v in
                     (a.served_mwh, a.dropped_mwh, a.arrivals_mwh))
    assert (srv + drp <= arr * (1.0 + 1e-5)).all()
    p10, p50, p90 = (np.asarray(v) for v in (a.cpc_p10, a.cpc_p50,
                                             a.cpc_p90))
    assert (p10 <= p50 + 1e-6).all() and (p50 <= p90 + 1e-6).all()
    c = workload_backtest(_grid(workload=Workload(n_draws=6, seed=3)))
    assert not np.array_equal(np.asarray(c.workload.cpc_p50), p50)


def test_demand_surge_reshapes_arrivals():
    wl = Workload(n_draws=4)
    grid = _grid(t=400)
    surge = FaultTrace(events=(
        FaultEvent("demand_surge", 0, 100, 50, magnitude=2.0),))
    base = workload_backtest(grid, wl).workload
    hit = workload_backtest(grid, wl, faults=surge).workload
    assert (np.asarray(hit.arrivals_mwh).mean()
            > np.asarray(base.arrivals_mwh).mean())
    # a surge-free schedule is the identity path (same sampled demand)
    quiet = workload_backtest(grid, wl, faults=FaultTrace()).workload
    np.testing.assert_array_equal(np.asarray(quiet.cpc),
                                  np.asarray(base.cpc))


def test_summarize_and_grid_carry_workload():
    wl = Workload(n_draws=4)
    grid = _grid(workload=wl)
    rep = backtest(grid, use_pallas=False)
    s = summarize(grid, rep)
    assert s.workload is not None and s.workload.n_draws == 4
    s0 = summarize(_grid(), backtest(_grid(), use_pallas=False))
    assert s0.workload is None
    # workload is a shared field: row permutations carry it
    perm = grid.take_rows(np.arange(grid.n_rows)[::-1])
    assert perm.workload is wl


def test_dispatch_config_workload_demand():
    wl = Workload()
    cfg = DispatchConfig(workload=wl)
    t = 48
    power = np.ones(2)
    np.testing.assert_allclose(resolve_demand(cfg, power, t),
                               wl.mean_demand_mw(t))
    # explicit demand wins over the workload spec
    cfg2 = DispatchConfig(demand_mw=1.5, workload=wl)
    np.testing.assert_allclose(resolve_demand(cfg2, power, t),
                               np.full(t, 1.5))


# ---------------------------------------------------------------------------
# SLO-aware tuning + live replay
# ---------------------------------------------------------------------------

def test_tune_workload_cost_bounded_by_best_swept():
    wl = Workload(n_draws=6)
    grid = _grid(t=300)
    res = optimize(grid, TuneConfig(steps=25, workload=wl))
    assert res.workload_cost is not None
    assert np.isfinite(res.workload_cost).all()
    # the selection sampled wl's own seeded draws — reproduce them
    wc_swept = np.asarray(realized_cost(
        grid, grid.p_on, grid.p_off, grid.off_level, wl,
        demand_mw=wl.sample_demand_mw(grid.n_hours)), np.float64)
    best = cell_best_rows(grid, wc_swept)
    assert (res.workload_cost <= wc_swept[best] + 1e-6).all()


def test_tune_without_workload_unchanged():
    grid = _grid(t=300)
    res = optimize(grid, TuneConfig(steps=10))
    assert res.workload_cost is None


def test_live_workload_replay_and_surge():
    wl = Workload(n_draws=6, base_rps=4.0)
    prices = np.asarray(_grid(t=400).prices)
    r = live_fleet_dispatch(prices, 1.0, 30.0, 60.0, 0.0, 0.0,
                            np.full(2, 0.25), start=200, hours=48,
                            workload=wl)
    w = r.workload
    assert set(w) >= {"served_mwh", "dropped_mwh", "deferred_mwh_h",
                      "cost", "cpc_p10", "cpc_p50", "cpc_p90"}
    assert w["served_mwh"].shape == (6,)
    assert w["cpc_p10"] <= w["cpc_p50"] <= w["cpc_p90"]
    surge = FaultTrace(events=(
        FaultEvent("demand_surge", 0, 210, 20, magnitude=3.0),))
    hit = live_fleet_dispatch(prices, 1.0, 30.0, 60.0, 0.0, 0.0,
                              np.full(2, 0.25), start=200, hours=48,
                              workload=wl, faults=surge)
    assert (np.mean(hit.workload["dropped_mwh"])
            >= np.mean(w["dropped_mwh"]))
    with pytest.raises(ValueError):
        live_fleet_dispatch(prices, 1.0, 30.0, 60.0, 0.0, 0.0,
                            np.full(2, 0.25), start=200, hours=48)


# ---------------------------------------------------------------------------
# golden digest (regenerate: REGEN_OBS_GOLDEN=1)
# ---------------------------------------------------------------------------

def _golden_run(run_dir) -> None:
    wl = Workload(n_draws=4, seed=1)
    with obs.capture(run_dir, run_id="workload_golden"):
        grid = _grid(workload=wl)
        workload_backtest(grid)


def test_workload_digest_matches_golden(tmp_path):
    run_dir = tmp_path / "run"
    _golden_run(run_dir)
    digest = render_digest(run_dir, redact_meta=True)
    assert "## Workload" in digest
    if F64:
        pytest.skip("golden rendered under default f32 numerics — the "
                    "scan's shutdown hours shift under x64")
    if os.environ.get("REGEN_OBS_GOLDEN"):
        GOLDEN.parent.mkdir(parents=True, exist_ok=True)
        GOLDEN.write_text(digest)
        pytest.skip(f"regenerated {GOLDEN}")
    assert GOLDEN.exists(), \
        "golden digest missing — run with REGEN_OBS_GOLDEN=1 to create"
    assert digest == GOLDEN.read_text(), (
        "digest drifted from tests/golden/workload_digest.md — if the "
        "change is intentional, regenerate with REGEN_OBS_GOLDEN=1")


# ---------------------------------------------------------------------------
# property-based (derandomized; skipped without hypothesis)
# ---------------------------------------------------------------------------

def _spec():
    return st.tuples(
        st.integers(min_value=1, max_value=24),          # T
        st.integers(min_value=0, max_value=5),           # deadline
        st.integers(min_value=0, max_value=8),           # bound
        st.integers(min_value=0, max_value=2 ** 31 - 1))  # seed


if HAVE_HYPOTHESIS:
    derandom = settings(derandomize=True, max_examples=60,
                        deadline=None)
else:
    derandom = settings()


@derandom
@given(_spec())
def test_prop_conservation(spec):
    t, d, bound, seed = spec
    g = np.random.default_rng(seed)
    a = g.integers(0, 7, t).astype(np.float64)
    c = g.integers(0, 7, t).astype(np.float64)
    rep = replay_ledger(a, c, deadline=d, bound=float(bound))
    carried_in = np.concatenate([[0.0], rep.backlog[:-1]])
    np.testing.assert_array_equal(a + carried_in,
                                  rep.served + rep.dropped + rep.backlog)
    # and the jax scan agrees exactly
    out, h = queue_scan(a, c, deadline=d, bound=float(bound),
                        hourly=True)
    np.testing.assert_array_equal(np.asarray(h.served), rep.served)
    np.testing.assert_array_equal(np.asarray(h.dropped), rep.dropped)


@derandom
@given(_spec())
def test_prop_backlog_never_exceeds_bound(spec):
    t, d, bound, seed = spec
    g = np.random.default_rng(seed)
    a = g.uniform(0.0, 7.0, t)
    c = g.uniform(0.0, 7.0, t)
    rep = replay_ledger(a, c, deadline=d, bound=float(bound))
    assert (rep.backlog <= bound + 1e-9).all()


@derandom
@given(_spec())
def test_prop_drop_cost_monotone_in_rate(spec):
    t, d, bound, seed = spec
    g = np.random.default_rng(seed)
    a = g.uniform(0.0, 7.0, t)
    c = g.uniform(0.0, 4.0, t)
    rep = replay_ledger(a, c, deadline=d, bound=float(bound))
    lo = ledger_cost(rep, slo_penalty_eur_mwh=40.0, voll_eur_mwh=1000.0)
    hi = ledger_cost(rep, slo_penalty_eur_mwh=40.0, voll_eur_mwh=4000.0)
    assert hi["drop_cost"] >= lo["drop_cost"]
    assert hi["drop_cost"] == pytest.approx(4.0 * lo["drop_cost"])


@derandom
@given(_spec())
def test_prop_more_capacity_never_drops_more(spec):
    t, d, bound, seed = spec
    g = np.random.default_rng(seed)
    a = g.integers(0, 7, t).astype(np.float64)
    c = g.integers(0, 5, t).astype(np.float64)
    extra = g.integers(0, 4, t).astype(np.float64)
    r1 = replay_ledger(a, c, deadline=d, bound=float(bound))
    r2 = replay_ledger(a, c + extra, deadline=d, bound=float(bound))
    assert np.sum(r2.dropped) <= np.sum(r1.dropped) + 1e-9
    assert np.sum(r2.served) >= np.sum(r1.served) - 1e-9
