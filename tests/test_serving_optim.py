"""Serving engine + optimizer component tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import get_config
from repro.configs.inputs import reduced_config
from repro.models.model import init_params
from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               clip_by_global_norm, global_norm)
from repro.optim.compress import dequantize, quantize_int8
from repro.optim.schedule import warmup_cosine
from repro.serving.engine import Request, ServeConfig, ServingEngine


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_model():
    cfg = reduced_config(get_config("qwen1.5-0.5b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _requests(cfg, n, seed=0, max_new=6):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(2, cfg.vocab - 1,
                                        size=8).astype(np.int32),
                    max_new=max_new) for i in range(n)]


def test_engine_completes_all_requests(small_model):
    cfg, params = small_model
    eng = ServingEngine(params, cfg, ServeConfig(slots=4, max_seq=32))
    for r in _requests(cfg, 10):
        eng.submit(r)
    out = eng.run(ticks=40)
    assert out["completed"] == 10
    assert out["tokens_served"] == 10 * 6
    assert all(len(r.output) == 6 for r in eng.completed)


def test_engine_greedy_matches_model(small_model):
    """Slot decoding must equal a straight prefill+decode_step loop."""
    from repro.models.model import decode_step, prefill
    cfg, params = small_model
    req = _requests(cfg, 1, seed=3, max_new=4)[0]
    eng = ServingEngine(params, cfg, ServeConfig(slots=2, max_seq=32))
    eng.submit(req)
    eng.run(ticks=10)
    got = eng.completed[0].output

    batch = {"tokens": jnp.asarray(req.prompt[None], jnp.int32)}
    logits, caches = prefill(params, batch, cfg, max_seq=32)
    want = [int(jnp.argmax(logits, -1)[0])]
    pos = len(req.prompt)
    for _ in range(3):
        logits, caches = decode_step(
            params, jnp.asarray([[want[-1]]], jnp.int32), caches,
            jnp.asarray([pos], jnp.int32), cfg)
        want.append(int(jnp.argmax(logits, -1)[0]))
        pos += 1
    assert got == want


def test_price_gate_blocks_admission(small_model):
    cfg, params = small_model

    class StubSched:
        p_thresh = 100.0
        class stream:                      # noqa: N801 - stub namespace
            @staticmethod
            def current():
                return 500.0               # always above threshold
        def step(self, hours):
            return None

    eng = ServingEngine(params, cfg,
                        ServeConfig(slots=4, min_slots=0, max_seq=32),
                        scheduler=StubSched())
    for r in _requests(cfg, 4):
        eng.submit(r)
    out = eng.run(ticks=10)
    assert out["completed"] == 0 and out["queued"] == 4


def test_min_slots_keeps_service_during_high_price(small_model):
    cfg, params = small_model

    class StubSched:
        p_thresh = 100.0
        class stream:                      # noqa: N801
            @staticmethod
            def current():
                return 500.0
        def step(self, hours):
            return None

    eng = ServingEngine(params, cfg,
                        ServeConfig(slots=4, min_slots=2, max_seq=32),
                        scheduler=StubSched())
    for r in _requests(cfg, 4):
        eng.submit(r)
    out = eng.run(ticks=30)
    assert out["completed"] == 4           # trickles through 2 slots


class _PriceStream:
    def __init__(self, price):
        self.price = price

    def current(self):
        return self.price


class _MutableSched:
    """Stub scheduler whose price can be flipped mid-run."""

    def __init__(self, price, thresh=100.0):
        self.stream = _PriceStream(price)
        self.p_thresh = thresh

    def step(self, hours):
        return None


def test_admission_width_shrinks_and_recovers(small_model):
    """Above the threshold the admission width collapses to
    ``min_slots``; when the price falls back below, the full width
    returns and the backlog drains."""
    cfg, params = small_model
    sched = _MutableSched(price=500.0)
    eng = ServingEngine(params, cfg,
                        ServeConfig(slots=4, min_slots=1, max_seq=32),
                        scheduler=sched)
    for r in _requests(cfg, 6, max_new=6):
        eng.submit(r)

    assert eng._admission_width() == 1
    for _ in range(3):
        eng.tick()
    # only the SLO floor is live while the price is high
    assert int(eng.live.sum()) == 1

    sched.stream.price = 50.0              # price relief
    assert eng._admission_width() == 4
    eng.tick()
    assert int(eng.live.sum()) == 4        # full width recovered
    out = eng.run(ticks=20)
    assert out["completed"] == 6 and out["queued"] == 0


def test_eur_per_1k_tokens_matches_tick_accounting(small_model):
    """The serving meter's EUR/1k-tokens must equal the independently
    integrated tick accounting: fixed cost accrues every tick, energy
    at the constant stub price is exactly ``energy_mwh * price``."""
    cfg, params = small_model
    price = 60.0
    scfg = ServeConfig(slots=2, max_seq=32, hours_per_tick=0.05,
                       power_mw=0.4, fixed_cost_per_hour=10.0)
    eng = ServingEngine(params, cfg, scfg,
                        scheduler=_MutableSched(price=price))
    for r in _requests(cfg, 3, max_new=5):
        eng.submit(r)
    ticks = 12
    out = eng.run(ticks=ticks)
    assert out["tokens_served"] == 3 * 5
    hours = ticks * scfg.hours_per_tick
    assert out["hours"] == pytest.approx(hours)
    assert out["fixed_cost"] == pytest.approx(
        scfg.fixed_cost_per_hour * hours)
    # constant price: the energy bill is the metered MWh at that price
    assert out["energy_cost"] == pytest.approx(
        out["energy_mwh"] * price)
    assert out["energy_mwh"] <= scfg.power_mw * hours + 1e-9
    tco = out["fixed_cost"] + out["energy_cost"]
    assert out["tco"] == pytest.approx(tco)
    assert out["eur_per_1k_tokens"] == pytest.approx(
        tco / out["tokens_served"] * 1000.0)
    assert eng.meter.tco == pytest.approx(tco)


def test_ssm_engine_serves(small_model):
    cfg = reduced_config(get_config("mamba2-1.3b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, ServeConfig(slots=2, max_seq=32))
    for r in _requests(cfg, 3, max_new=4):
        eng.submit(r)
    out = eng.run(ticks=20)
    assert out["completed"] == 3


# ---------------------------------------------------------------------------
# optimizer pieces
# ---------------------------------------------------------------------------

def test_adamw_first_step_matches_manual():
    opt = AdamWConfig(lr=0.1, b1=0.9, b2=0.999, eps=1e-8,
                      weight_decay=0.0, clip_norm=0.0)
    params = {"w": jnp.asarray([1.0, -2.0])}
    grads = {"w": jnp.asarray([0.5, 0.5])}
    state = adamw_init(params, opt)
    new_p, new_s, _ = adamw_update(grads, state, params, opt)
    # bias-corrected first step: mhat = g, vhat = g^2 -> delta ~ sign(g)
    want = params["w"] - 0.1 * grads["w"] / (jnp.abs(grads["w"]) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), np.asarray(want),
                               rtol=1e-4)
    assert int(new_s.step) == 1


def test_weight_decay_pulls_toward_zero():
    opt = AdamWConfig(lr=0.1, weight_decay=0.5, clip_norm=0.0)
    params = {"w": jnp.asarray([10.0])}
    grads = {"w": jnp.asarray([0.0])}
    state = adamw_init(params, opt)
    new_p, _, _ = adamw_update(grads, state, params, opt)
    assert float(new_p["w"][0]) < 10.0


def test_clip_by_global_norm():
    grads = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(5.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_warmup_cosine_shape():
    lr0 = float(warmup_cosine(0, 1.0, 10, 100))
    lr_w = float(warmup_cosine(10, 1.0, 10, 100))
    lr_end = float(warmup_cosine(100, 1.0, 10, 100))
    assert lr0 == pytest.approx(0.0)
    assert lr_w == pytest.approx(1.0)
    assert lr_end == pytest.approx(0.1, rel=1e-3)


@given(st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                min_size=4, max_size=64))
@settings(max_examples=50, deadline=None)
def test_quantize_int8_error_bound(xs):
    x = jnp.asarray(xs, jnp.float32)
    q, scale, err = quantize_int8(x, jnp.zeros_like(x))
    deq = dequantize(q, scale)
    # quantisation error bounded by scale/2 elementwise (+ residual carried)
    assert float(jnp.max(jnp.abs(x - deq))) <= float(scale) * 0.5 + 1e-6
    np.testing.assert_allclose(np.asarray(x - deq), np.asarray(err),
                               atol=1e-6)


def test_error_feedback_converges_in_mean():
    """Repeated quantisation of the same gradient with error feedback must
    deliver the true mean value over time (unbiasedness in practice)."""
    g = jnp.asarray([0.003, -0.002, 0.001], jnp.float32)
    err = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    for _ in range(200):
        q, s, err = quantize_int8(g, err)
        acc = acc + dequantize(q, s)
    np.testing.assert_allclose(np.asarray(acc / 200), np.asarray(g),
                               rtol=0.02, atol=1e-5)
