"""Fault-injection subsystem tests: the zero-fault bit-identity
contract of `faulted_backtest` (telemetry on and off, including the
256-row acceptance grid), `FaultTrace` compilation and validation,
relief-mode dispatch properties (zero-shed relief bitwise equal to the
hard dispatcher, shed cost exactly linear in VoLL), the tuner's
non-finite step guard (healthy runs bitwise unperturbed, poisoned runs
survive with finite results), checkpoint kill/resume bit-identity of
`tune_loop_checkpointed`, the live controller's degradation ladder,
and the gap-fill/staleness accounting of the data layer."""

import dataclasses
import json
import pathlib
import shutil

import numpy as np
import pytest

from repro import obs
from repro.core.tco import make_system
from repro.dispatch import (DispatchConfig, DispatchInfeasible,
                            DispatchProblem, Relief, dispatch,
                            segment_rank)
from repro.energy.markets import MarketParams
from repro.energy.stream import PriceStream, ffill_with_staleness
from repro.faults import (FAULT_KINDS, FaultEvent, FaultMasks, FaultTrace,
                          faulted_backtest, faulted_problem,
                          identity_masks, random_storm)
from repro.fleet import PolicySpec, backtest, build_grid, summarize
from repro.live import LiveConfig, build_live_grid, live_backtest
from repro.obs.report import load_events, render_digest
from repro.obs.schema import validate
from repro.tune import TuneConfig, optimize, tune_loop_checkpointed
from repro.tune.objective import init_from_grid, problem_from_grid

import jax.numpy as jnp


def _grid(n_markets=2, t=400):
    markets = [MarketParams(n_hours=t, seed=s) for s in range(n_markets)]
    sys = make_system(0.5 * t * 80.0, 1.0, float(t))
    pols = [PolicySpec("ao"), PolicySpec("x10", x=0.10, off_level=0.3),
            PolicySpec("x30", x=0.30, off_level=0.3)]
    return build_grid(markets, [sys], pols)


def _acceptance_grid():
    """The fixed-seed 256-row grid shared with tests/test_tune.py."""
    t = 600
    markets = [MarketParams(n_hours=t, seed=s) for s in range(4)]
    systems = [make_system(float(psi) * t * 1.0 * 80.0, 1.0, float(t))
               for psi in (0.5, 1.0, 2.0, 4.0)]
    xs = (0.01, 0.02, 0.03, 0.05, 0.08, 0.10, 0.12, 0.15,
          0.20, 0.25, 0.30, 0.40)
    policies = [PolicySpec("ao")] + \
        [PolicySpec(f"x{int(x * 100)}", x=x, off_level=0.25)
         for x in xs] + \
        [PolicySpec("x3h", x=0.03, hysteresis=0.9, off_level=0.25),
         PolicySpec("x8h", x=0.08, hysteresis=0.85, off_level=0.25),
         PolicySpec("x15h", x=0.15, hysteresis=0.9, off_level=0.25)]
    return build_grid(markets, systems, policies)


def _assert_reports_equal(a, b):
    for f in a._fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)),
                                      err_msg=f)


def _problem(s=4, t=300, *, demand_frac=0.5, seed=17, migrate_cost=0.0):
    r = np.random.default_rng(seed)
    prices = r.normal(80, 40, (s, t)).astype(np.float32)
    power = r.uniform(1.0, 3.0, s).astype(np.float32)
    on = (r.uniform(size=(s, t)) > 0.3).astype(np.float32)
    avail = power[:, None] * (0.2 + 0.8 * on)
    demand = np.full(t, demand_frac * float(avail.sum(axis=0).min()),
                     np.float32)
    order, rank = segment_rank(prices, migrate_cost)
    return DispatchProblem(
        prices=prices, avail_mw=avail, demand_mw=demand,
        power_cap_mw=float("inf"), migrate_cost=migrate_cost,
        min_dwell_h=0, compute_floor_mwh=0.0, fixed_cost=0.0,
        order=order, rank=rank)


# ---------------------------------------------------------------------------
# FaultTrace schema
# ---------------------------------------------------------------------------

def test_fault_trace_validation():
    with pytest.raises(ValueError):
        FaultTrace(events=(FaultEvent("quake", 0, 0, 1),))
    with pytest.raises(ValueError):
        FaultTrace(events=(FaultEvent("site_outage", 0, -1, 1),))
    # zero-duration events are legal no-ops (compile to trivial masks)
    assert FaultTrace(events=(FaultEvent("site_outage", 0, 0, 0),)) \
        .compile(2, 2, 10).is_trivial
    assert len(FaultTrace()) == 0
    assert FaultTrace().compile(2, 2, 10).is_trivial


def test_fault_trace_compile_masks():
    tr = FaultTrace(events=(
        FaultEvent("site_outage", 1, 5, 3),
        FaultEvent("price_gap", 0, 2, 4),
        FaultEvent("forecast_blackout", 0, 0, 2),
        FaultEvent("demand_surge", 0, 6, 2, magnitude=1.5)))
    m = tr.compile(2, 2, 12)
    assert not m.is_trivial
    np.testing.assert_array_equal(np.asarray(m.cap_mult[1, 5:8]), 0.0)
    assert float(np.asarray(m.cap_mult).sum()) == 2 * 12 - 3
    assert not np.asarray(m.price_ok)[0, 2:6].any()
    assert not np.asarray(m.forecast_ok)[0, :2].any()
    np.testing.assert_allclose(np.asarray(m.demand_mult[6:8]), 1.5)
    counts = m.counts()
    assert counts["outage_site_hours"] == 3
    assert counts["price_gap_hours"] == 4


def test_random_storm_seeded_and_bounded():
    a = random_storm(7, 4, 2, 200)
    b = random_storm(7, 4, 2, 200)
    assert a == b
    assert random_storm(8, 4, 2, 200) != a
    for ev in a.events:
        assert ev.kind in FAULT_KINDS
        assert 0 <= ev.start < 200
        assert ev.start + ev.duration <= 200


# ---------------------------------------------------------------------------
# zero-fault bit-identity
# ---------------------------------------------------------------------------

def test_zero_fault_backtest_bit_identical():
    grid = _grid()
    ref = backtest(grid, use_pallas=False)
    for faults in (None, FaultTrace(),
                   identity_masks(grid.n_rows, 2, 400)):
        _assert_reports_equal(ref, faulted_backtest(grid, faults))
    # the masked program itself (not the trivial-mask short-circuit) is
    # also bitwise the plain backtest: identity masks reduce every
    # fault channel to where(True, x) / * 1.0
    _assert_reports_equal(
        ref, faulted_backtest(grid, None, _force_masked=True))


def test_zero_fault_bit_identical_on_acceptance_grid(tmp_path):
    """The acceptance contract: on the 256-row grid the zero-fault
    faulted path is bitwise the plain backtest — with telemetry off
    AND on (fault channels may not perturb through the obs layer)."""
    grid = _acceptance_grid()
    assert grid.n_rows == 256
    ref = backtest(grid, use_pallas=False)
    _assert_reports_equal(ref, faulted_backtest(grid, _force_masked=True))
    obs.enable(tmp_path / "run", run_id="zf")
    try:
        traced = faulted_backtest(grid, _force_masked=True)
    finally:
        obs.disable()
    _assert_reports_equal(ref, traced)
    # an empty schedule emits no fault events
    events = load_events(tmp_path / "run")
    assert not [e for e in events if e["kind"] == "fault.injected"]


def test_faulted_backtest_degrades_not_crashes():
    grid = _grid()
    ref = backtest(grid, use_pallas=False)
    storm = random_storm(3, grid.n_rows, 2, 400)
    rep = faulted_backtest(grid, storm)
    assert np.isfinite(np.asarray(rep.cpc)).all()
    assert not np.array_equal(np.asarray(rep.cpc), np.asarray(ref.cpc))
    # a pure outage only ever removes compute (price gaps, by contrast,
    # can keep stale-decided units running longer)
    outage = FaultTrace(events=(FaultEvent("site_outage", 1, 100, 40),))
    out = faulted_backtest(grid, outage)
    assert (np.asarray(out.up_hours)
            <= np.asarray(ref.up_hours) + 1e-6).all()
    assert np.asarray(out.up_hours)[1] < np.asarray(ref.up_hours)[1]


def test_faulted_problem_trivial_identity_and_surge():
    prob = _problem()
    assert faulted_problem(prob, FaultTrace()) is prob
    surge = FaultTrace(events=(
        FaultEvent("demand_surge", 0, 10, 20, magnitude=1.3),))
    fp = faulted_problem(prob, surge)
    np.testing.assert_allclose(np.asarray(fp.demand_mw[10:30]),
                               np.asarray(prob.demand_mw[10:30]) * 1.3,
                               rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(fp.avail_mw),
                                  np.asarray(prob.avail_mw))


# ---------------------------------------------------------------------------
# dispatch relief mode
# ---------------------------------------------------------------------------

def test_relief_zero_shed_bitwise_equal_to_hard():
    """On a feasible problem the relief dispatcher sheds nothing and the
    result is bitwise the hard dispatcher's."""
    prob = _problem(demand_frac=0.4)
    hard = dispatch(prob)
    soft = dispatch(prob._replace(relief=Relief()))
    assert soft.shed_mwh == 0.0
    assert soft.shed_cost == 0.0
    assert soft.n_shed_hours == 0
    for f in hard._fields:
        if f in ("shed_mwh", "shed_cost", "n_shed_hours"):
            continue
        np.testing.assert_array_equal(np.asarray(getattr(hard, f)),
                                      np.asarray(getattr(soft, f)),
                                      err_msg=f)


def test_relief_sheds_instead_of_raising():
    prob = _problem(demand_frac=0.4)
    outage = FaultTrace(events=(
        FaultEvent("site_outage", 0, 50, 30),
        FaultEvent("site_outage", 1, 55, 30),
        FaultEvent("site_outage", 2, 60, 30),
        FaultEvent("site_outage", 3, 60, 20),))
    fp = faulted_problem(prob, outage)
    with pytest.raises(DispatchInfeasible):
        dispatch(fp)
    res = dispatch(fp._replace(relief=Relief(voll_eur_mwh=1000.0)))
    assert res.shed_mwh > 0.0
    assert res.n_shed_hours > 0
    assert np.isfinite(res.cpc)


def test_relief_shed_cost_linear_in_voll():
    prob = _problem(demand_frac=0.4)
    fp = faulted_problem(prob, FaultTrace(events=tuple(
        FaultEvent("site_outage", k, 50, 25) for k in range(4))))
    runs = {v: dispatch(fp._replace(relief=Relief(voll_eur_mwh=v)))
            for v in (500.0, 2500.0, 5000.0)}
    shed = {v: r.shed_mwh for v, r in runs.items()}
    # the shed profile is VoLL-independent (exact water-fill shortfall)
    assert shed[500.0] == shed[2500.0] == shed[5000.0]
    np.testing.assert_allclose(runs[2500.0].shed_cost,
                               5 * runs[500.0].shed_cost, rtol=1e-12)
    np.testing.assert_allclose(runs[5000.0].shed_cost,
                               10 * runs[500.0].shed_cost, rtol=1e-12)
    assert runs[500.0].cpc < runs[2500.0].cpc < runs[5000.0].cpc


# ---------------------------------------------------------------------------
# tuner guard + checkpoint/resume
# ---------------------------------------------------------------------------

def _tune_fixture(t=240):
    markets = [MarketParams(n_hours=t, seed=s) for s in range(2)]
    systems = [make_system(0.6 * t * 1.0 * 60.0, 1.0, float(t))]
    pols = [PolicySpec(f"x{int(x * 100)}", x=x, off_level=0.4)
            for x in (0.1, 0.3, 0.5)]
    return build_grid(markets, systems, pols)


def test_tuner_guard_noop_on_healthy_run():
    grid = _tune_fixture()
    a = optimize(grid, TuneConfig(steps=40))
    b = optimize(grid, TuneConfig(steps=40))
    assert a.guard_count == 0
    assert float(np.sum(a.history["guard_rejects"])) == 0.0
    np.testing.assert_array_equal(np.asarray(a.cpc), np.asarray(b.cpc))
    for fa, fb in zip(a.raw, b.raw):
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))


def test_tuner_guard_survives_poisoned_input():
    """A NaN in one market's price trace poisons every loss/grad that
    touches it; the guard must reject those steps (count them) and
    still return finite parameters for the healthy rows."""
    grid = _tune_fixture()
    bad = dataclasses.replace(
        grid, prices=grid.prices.at[0, 5].set(jnp.nan))
    res = optimize(bad, TuneConfig(steps=40))
    assert res.guard_count > 0
    for f in res.raw:
        assert np.isfinite(np.asarray(f)).all()


def test_tune_checkpoint_kill_resume_bit_identical(tmp_path):
    grid = _tune_fixture()
    problem = problem_from_grid(grid)
    raw0 = init_from_grid(grid)
    cfg = TuneConfig(steps=40)
    d1, d2 = tmp_path / "a", tmp_path / "b"
    raw_a, hist_a, cpc_a = tune_loop_checkpointed(
        raw0, problem, cfg=cfg, directory=d1)
    # run to completion, then "crash" by deleting everything after the
    # second stage checkpoint and resume from what survived
    tune_loop_checkpointed(raw0, problem, cfg=cfg, directory=d2)
    for p in sorted(pathlib.Path(d2).glob("step_*"))[2:]:
        shutil.rmtree(p)
    raw_b, hist_b, cpc_b = tune_loop_checkpointed(
        raw0, problem, cfg=cfg, directory=d2)
    for fa, fb in zip(raw_a, raw_b):
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))
    np.testing.assert_array_equal(np.asarray(cpc_a), np.asarray(cpc_b))
    for k in hist_a:
        np.testing.assert_array_equal(np.asarray(hist_a[k]),
                                      np.asarray(hist_b[k]), err_msg=k)


# ---------------------------------------------------------------------------
# live degradation ladder
# ---------------------------------------------------------------------------

def _live_fixture(t=600):
    markets = [MarketParams(n_hours=t, seed=s) for s in range(2)]
    systems = [make_system(0.6 * t * 1.0 * 60.0, 1.0, float(t))]
    pols = [PolicySpec("x30", x=0.3, off_level=0.4),
            PolicySpec("x10", x=0.1, off_level=0.4)]
    grid = build_grid(markets, systems, pols)
    return build_live_grid(grid, pols,
                           forecasters=("seasonal_naive", "persistence"),
                           families=("quantile", "tuned"))


def test_live_zero_fault_bit_identical():
    lg = _live_fixture()
    cfg = LiveConfig(hours=336, start=170)
    ref = live_backtest(lg, cfg)
    for faults in (None, FaultTrace()):
        got = live_backtest(lg, cfg, faults=faults)
        for f in ref._fields:
            np.testing.assert_array_equal(np.asarray(getattr(ref, f)),
                                          np.asarray(getattr(got, f)),
                                          err_msg=f)


def test_live_fallback_ladder_under_storm(tmp_path):
    lg = _live_fixture()
    cfg = LiveConfig(hours=336, start=170)
    ref = live_backtest(lg, cfg)
    storm = FaultTrace(events=(
        FaultEvent("site_outage", 0, 200, 24),
        FaultEvent("price_gap", 0, 250, 12),
        FaultEvent("forecast_blackout", 1, 300, 60)), seed=7)
    obs.enable(tmp_path / "run", run_id="lf")
    try:
        res = live_backtest(lg, cfg, faults=storm)
    finally:
        obs.disable()
    assert np.isfinite(np.asarray(res.cpc)).all()
    assert not np.array_equal(np.asarray(res.cpc), np.asarray(ref.cpc))
    events = load_events(tmp_path / "run")
    for e in events:
        assert validate(e) == [], e
    fb = [e for e in events if e["kind"] == "live.fallback"]
    assert len(fb) == 1
    f = fb[0]
    # every row-hour lands on exactly one rung
    total = f["fresh"] + f["stale_shift"] + f["seasonal_naive"] \
        + f["persistence"]
    assert total == lg.n_rows * cfg.hours
    # the 60 h blackout outlasts every horizon, so the ladder must
    # reach past the age-shifted rung
    assert f["stale_shift"] > 0
    assert f["seasonal_naive"] > 0
    assert f["forced_off_row_hours"] > 0
    assert [e for e in events if e["kind"] == "fault.injected"]


# ---------------------------------------------------------------------------
# data-layer gap filling
# ---------------------------------------------------------------------------

def test_ffill_with_staleness_units():
    vals = np.array([np.nan, 10.0, np.nan, np.nan, 40.0])
    filled, stale = ffill_with_staleness(vals, fill_value=5.0)
    np.testing.assert_allclose(filled, [5.0, 10.0, 10.0, 10.0, 40.0])
    np.testing.assert_array_equal(stale, [1, 0, 1, 2, 0])


def test_price_stream_ffill_mode():
    prices = np.array([50.0, np.nan, np.nan, 80.0, 90.0])
    with pytest.raises(ValueError):
        PriceStream(prices)
    st = PriceStream(prices, fill="ffill")
    np.testing.assert_allclose(np.asarray(st.prices),
                               [50.0, 50.0, 50.0, 80.0, 90.0])
    np.testing.assert_array_equal(np.asarray(st.staleness),
                                  [0, 1, 2, 0, 0])


def test_smard_csv_ffill_counts_filled(tmp_path):
    from repro.energy.smard import load_smard_csv
    csv = tmp_path / "p.csv"
    csv.write_text("Datum;Preis\na;50,5\nb;-\nc;-\nd;70,0\n")
    p, stats = load_smard_csv(str(csv), return_stats=True, fill="ffill")
    np.testing.assert_allclose(p, [50.5, 50.5, 50.5, 70.0])
    assert stats.n_filled == 2
    assert stats.n_nan == 2
    # filled hours no longer count toward the skip fraction
    assert stats.skip_frac == 0.0


def test_summarize_nan_safe_with_degraded_rows():
    """A degraded report row (inf CPC from a fully-outaged site) must
    not poison the fleet summary's totals or the regret table."""
    grid = _grid()
    rep = backtest(grid, use_pallas=False)
    bad = rep._replace(
        cpc=rep.cpc.at[0].set(jnp.inf),
        cpc_reduction=rep.cpc_reduction.at[0].set(jnp.nan),
        tco=rep.tco.at[0].set(jnp.inf))
    s = summarize(grid, bad)
    assert np.isfinite(s.total_cost)
    assert np.isfinite(s.energy_by_policy).all()


# ---------------------------------------------------------------------------
# obs integration: the Degradation digest section
# ---------------------------------------------------------------------------

def test_degradation_digest_section(tmp_path):
    grid = _grid()
    storm = random_storm(5, grid.n_rows, 2, 400)
    run = tmp_path / "run"
    obs.enable(run, run_id="dg")
    try:
        faulted_backtest(grid, storm)
    finally:
        obs.disable()
    for e in load_events(run):
        assert validate(e) == [], e
    digest = render_digest(run, redact_meta=True)
    assert "## Degradation" in digest
    assert "faults injected" in digest
    # healthy traces keep the section out (golden digest unchanged)
    run2 = tmp_path / "run2"
    obs.enable(run2, run_id="dg2")
    try:
        faulted_backtest(grid)
    finally:
        obs.disable()
    assert "## Degradation" not in render_digest(run2, redact_meta=True)
