"""Live-operator subsystem tests (`repro.live` + the forecast/stream
satellites): strict forecaster causality (property-based), the
seasonal-naive wrap-bug regression, numpy-vs-batched forecast parity,
the day-ahead publication-lag contract of `PriceStream`, the regret
sandwich (hindsight oracle <= live <= never too far from offline), the
perfect-forecast/full-horizon convergence of the live loop to the
offline backtest, live cross-site dispatch agreement with the offline
`dispatch_ref` on the never-re-solve path, warm-started re-tuning, and
the `repro.obs` zero-perturbation contract for ``live.*`` events."""

import numpy as np
import pytest

from repro import obs
from repro.core.tco import make_system
from repro.dispatch import segment_rank
from repro.energy.forecast import (effective_season, mae, mase,
                                   seasonal_naive, seasonal_naive_batch,
                                   similar_day_ar, similar_day_ar_batch)
from repro.energy.stream import PriceStream
from repro.fleet import PolicySpec, backtest, build_grid
from repro.kernels.live_window import (dispatch_window, plan_on_window,
                                       segment_keys_jnp, segment_rank_jnp)
from repro.kernels.ref import dispatch_alloc_hour, dispatch_ref
from repro.live import (FORECASTERS, LiveConfig, build_live_grid,
                        hindsight_cpc, live_backtest, live_fleet_dispatch,
                        offline_cpc, summarize_live)
from repro.obs.report import load_events
from repro.obs.schema import validate
from repro.tune import TuneConfig, optimize

from tests._hypothesis_compat import given, settings, st

rng = np.random.default_rng(42)


def _periodic(t, season=168, seed=0):
    r = np.random.default_rng(seed)
    base = r.normal(80.0, 30.0, season)
    reps = -(-t // season)
    return np.tile(base, reps)[:t].astype(np.float64)


# ---------------------------------------------------------------------------
# forecast baselines
# ---------------------------------------------------------------------------

def test_seasonal_naive_exact_on_periodic_series():
    """On a perfectly periodic series the seasonal-naive forecast must
    equal the truth even when horizon >> season — the old ``% len``
    wrap produced phase errors here whenever len(history) was not a
    multiple of the season."""
    season = 48
    hist_len = season * 3 + 7          # NOT a season multiple
    horizon = 3 * season
    series = _periodic(hist_len + horizon, season)
    pred = seasonal_naive(series[:hist_len], horizon, season)
    np.testing.assert_allclose(pred, series[hist_len:hist_len + horizon],
                               rtol=0, atol=0)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 400), st.integers(1, 50))
def test_forecast_causality_property(seed, horizon, perturb):
    """A forecast may depend only on the last ``season`` (+1 for the AR
    residual) samples: perturbing anything older must not change it."""
    season = 72
    n = season + 1 + perturb
    r = np.random.default_rng(seed)
    hist = r.normal(60.0, 25.0, n)
    tail = n - (season + 1)
    mangled = hist.copy()
    mangled[:tail] = r.normal(1e4, 1e3, tail)   # wreck the old past
    for fn in (seasonal_naive, similar_day_ar):
        a = fn(hist, horizon, season)
        b = fn(mangled, horizon, season)
        np.testing.assert_array_equal(a, b, err_msg=fn.__name__)


def test_batched_forecasts_match_numpy():
    season = 168
    w = season + 1
    hist = rng.normal(70.0, 35.0, (5, w)).astype(np.float32)
    for horizon in (1, 24, season, 2 * season + 5):
        got = np.asarray(seasonal_naive_batch(hist, horizon, season))
        want = seasonal_naive(hist, horizon, season)
        np.testing.assert_array_equal(got, want)
        got = np.asarray(similar_day_ar_batch(hist, horizon, season))
        want = similar_day_ar(hist, horizon, season)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4)


def test_effective_season_fallbacks():
    assert effective_season(200, 168) == 168
    assert effective_season(167, 168) == 24
    assert effective_season(23, 168) == 1
    # short history must still produce a finite forecast
    pred = seasonal_naive(np.arange(10.0), 48, season=168)
    assert pred.shape == (48,) and np.all(np.isfinite(pred))


def test_mase_scale_free_skill_score():
    season = 24
    series = _periodic(season * 10, season, seed=3)
    hist, truth = series[:-season], series[-season:]
    pred = seasonal_naive(hist, season, season)
    assert mase(pred, truth, hist, season) == pytest.approx(0.0, abs=1e-9)
    assert mae(truth, truth) == 0.0
    # noisy history: the in-sample seasonal-naive MAE is a real scale
    r = np.random.default_rng(7)
    hist_n = hist + r.normal(0, 5, hist.shape)
    noisy = pred + r.normal(0, 50, season)
    assert mase(noisy, truth, hist_n, season) > 1.0
    # scale invariance: same score after multiplying prices by 1000
    assert mase(1e3 * noisy, 1e3 * truth, 1e3 * hist_n, season) == \
        pytest.approx(mase(noisy, truth, hist_n, season), rel=1e-9)


# ---------------------------------------------------------------------------
# price stream: day-ahead publication lag
# ---------------------------------------------------------------------------

def test_stream_publication_lag_contract():
    prices = np.arange(24.0 * 5)
    s = PriceStream(prices, publish_hour=13, start=0)
    # hour 0: only today is published
    assert s.available_lookahead == 23
    s.advance(12.0)                    # hour 12 < 13: still just today
    assert s.available_lookahead == 11
    s.advance(1.0)                     # hour 13: tomorrow publishes
    assert s.available_lookahead == 24 + 10
    assert len(s.peek(1000)) == 34
    assert len(s.peek(5)) == 5
    np.testing.assert_array_equal(s.peek(3), prices[14:17])
    # the gate is relative to absolute hour-of-day, not stream age
    s2 = PriceStream(prices, publish_hour=13, start=20)
    assert s2.available_lookahead == 27     # hod 20 >= 13
    # None disables the gate entirely
    s3 = PriceStream(prices, publish_hour=None)
    assert s3.available_lookahead >= len(prices)
    with pytest.raises(ValueError):
        PriceStream(prices, publish_hour=24)


def test_stream_reset_and_iter_determinism():
    prices = rng.normal(50, 20, 240)
    s = PriceStream(prices, start=7)
    first = np.asarray(list(s))
    assert first.shape == (240,)
    assert s.pos == 7 + 240            # __iter__ advances, never rewinds
    s.reset()
    second = np.asarray(list(s))
    np.testing.assert_array_equal(first, second)
    # fractional ticks accumulate without loss
    s.reset()
    for _ in range(50):
        s.advance(0.02)
    assert s.pos == 7 + 1


# ---------------------------------------------------------------------------
# live controller: fixtures
# ---------------------------------------------------------------------------

def _live_case(t=336, n_markets=3, horizons=(24,), cadences=(1,),
               families=("quantile",), forecasters=FORECASTERS,
               policies=None, seed=11):
    r = np.random.default_rng(seed)
    prices = np.abs(r.normal(80.0, 40.0, (n_markets, t))) \
        .astype(np.float32)
    systems = [make_system(5e4, 1.0, float(t))]
    if policies is None:
        policies = [PolicySpec("x25", x=0.25),
                    PolicySpec("x10", x=0.10),
                    PolicySpec("always_on")]
    grid = build_grid(prices, systems, policies)
    lgrid = build_live_grid(grid, policies, forecasters=forecasters,
                            horizons=horizons, cadences=cadences,
                            families=families)
    return grid, lgrid


def test_build_live_grid_validation():
    grid, _ = _live_case()
    pols = [PolicySpec("x25", x=0.25), PolicySpec("x10", x=0.10),
            PolicySpec("always_on")]
    with pytest.raises(ValueError, match="policies"):
        build_live_grid(grid, pols[:1])
    with pytest.raises(ValueError, match="forecaster"):
        build_live_grid(grid, pols, forecasters=("oracle",))
    with pytest.raises(ValueError, match="horizons"):
        build_live_grid(grid, pols, horizons=(1,))
    lg = build_live_grid(grid, pols, horizons=(24, 48), cadences=(1, 6),
                         families=("quantile", "tuned"))
    assert lg.n_rows == grid.n_rows * len(FORECASTERS) * 2 * 2 * 2
    assert lg.h_max == 48
    # always_on rows ride along with x = 0 (never re-solve)
    x = np.asarray(lg.x)
    pol = np.asarray(lg.grid.policy_idx)
    assert np.all(x[pol == 2] == 0.0) and np.all(x[pol == 0] == 0.25)


def test_live_backtest_deterministic():
    _, lgrid = _live_case(t=240, n_markets=2, forecasters=(
        "seasonal_naive", "persistence"), families=("quantile", "tuned"))
    cfg = LiveConfig(hours=240, season=48)
    a = live_backtest(lgrid, cfg)
    b = live_backtest(lgrid, cfg)
    for fa, fb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))
    assert np.all(np.isfinite(np.asarray(a.cpc)))


def test_live_telemetry_bit_identical_and_schema_valid(tmp_path):
    _, lgrid = _live_case(t=240, n_markets=2,
                          forecasters=("seasonal_naive",),
                          families=("quantile", "tuned"))
    cfg = LiveConfig(hours=168, season=48)
    cold = live_backtest(lgrid, cfg)
    assert not obs.enabled()
    with obs.capture(tmp_path / "run"):
        hot = live_backtest(lgrid, cfg)
        summarize_live(lgrid, hot, cfg)
    for fc, fh in zip(cold, hot):
        np.testing.assert_array_equal(np.asarray(fc), np.asarray(fh))
    events = load_events(tmp_path / "run")
    kinds = {e["kind"] for e in events}
    assert {"live.step", "live.result"} <= kinds
    for e in events:
        assert validate(e) == [], e["kind"]
    step = next(e for e in events if e["kind"] == "live.step")
    assert len(step["on_mw"]) == cfg.hours
    res = next(e for e in events if e["kind"] == "live.result")
    assert res["rows"] == lgrid.n_rows and res["hours"] == cfg.hours


def test_regret_sandwich():
    """On a restart-free grid the clairvoyant oracle lower-bounds every
    live controller row (to f32 accumulation noise). >= 256 rows:
    3 markets x 3 policies x 4 forecasters x 2 horizons x 2 cadences x
    2 families = 288."""
    _, lgrid = _live_case(t=336, n_markets=3, horizons=(24, 336),
                          cadences=(1, 24),
                          families=("quantile", "tuned"))
    assert lgrid.n_rows >= 256
    cfg = LiveConfig(hours=336, season=168)
    res = live_backtest(lgrid, cfg)
    live = np.asarray(res.cpc, np.float64)
    oracle = hindsight_cpc(lgrid, cfg)
    assert np.all(oracle <= live * (1 + 1e-5) + 1e-6), \
        f"oracle exceeds live by {np.max(oracle - live):.3g}"
    # and the oracle is not vacuous: strictly below the mean live CPC
    assert oracle.mean() < live.mean()


def test_perfect_forecast_full_horizon_matches_offline():
    """Zero forecast error + horizon = T + cadence 1 removes every live
    handicap: the quantile family re-resolves the same full-window
    threshold every hour, and realized CPC must match the offline
    backtest on the same window."""
    t = 336
    grid, lgrid = _live_case(t=t, n_markets=3, horizons=(24, t),
                             cadences=(1,), forecasters=(
                                 "seasonal_naive", "perfect"))
    cfg = LiveConfig(hours=t, season=168)
    res = live_backtest(lgrid, cfg)
    fid = np.asarray(lgrid.forecaster_id)
    hor = np.asarray(lgrid.horizon)
    sel = (fid == FORECASTERS.index("perfect")) & (hor == t)
    assert sel.sum() >= 3
    live = np.asarray(res.cpc, np.float64)[sel]
    offline = np.asarray(backtest(grid, use_pallas=False).cpc,
                         np.float64)[np.asarray(lgrid.base_row)[sel]]
    np.testing.assert_allclose(live, offline, rtol=1e-6, atol=1e-6)
    # offline_cpc agrees with the engine it wraps on the full window
    np.testing.assert_allclose(
        offline_cpc(lgrid, cfg)[sel],
        np.asarray(backtest(grid, use_pallas=False).cpc,
                   np.float64)[np.asarray(lgrid.base_row)[sel]],
        rtol=1e-6)


def test_summarize_live_groups_and_orders():
    _, lgrid = _live_case(t=240, n_markets=2, horizons=(24, 48),
                          forecasters=("seasonal_naive", "perfect"))
    cfg = LiveConfig(hours=168, season=48)
    summary = summarize_live(lgrid, live_backtest(lgrid, cfg), cfg)
    assert len(summary.table) == 2 * 2      # forecaster x horizon groups
    cpcs = [r["cpc"] for r in summary.table]
    assert cpcs == sorted(cpcs)
    assert sum(r["rows"] for r in summary.table) == lgrid.n_rows
    rendered = summary.render_table()
    assert "perfect" in rendered and "seasonal_naive" in rendered
    assert np.all(summary.regret_oracle >= -1e-5)


# ---------------------------------------------------------------------------
# warm-started re-tuning (tune.optimize warm_start)
# ---------------------------------------------------------------------------

def test_optimize_warm_start_continues_descent():
    prices = np.abs(rng.normal(80, 40, (2, 240))).astype(np.float32)
    grid = build_grid(prices, [make_system(2e4, 1.0, 240.0)],
                      [PolicySpec("x10", x=0.10)])
    cold = optimize(grid, TuneConfig(steps=30))
    warm = optimize(grid, TuneConfig(steps=10), warm_start=cold)
    assert np.all(warm.cpc <= cold.cpc * (1 + 1e-6))
    # PhysicalPolicy and PolicyParams entry points both round-trip
    via_params = optimize(grid, TuneConfig(steps=5), warm_start=cold.raw)
    via_policy = optimize(grid, TuneConfig(steps=5),
                          warm_start=cold.params)
    assert np.all(np.isfinite(via_params.cpc))
    assert np.all(np.isfinite(via_policy.cpc))
    with pytest.raises(TypeError):
        optimize(grid, TuneConfig(steps=1), warm_start=np.zeros(2))


# ---------------------------------------------------------------------------
# live cross-site dispatch
# ---------------------------------------------------------------------------

def _fleet_case(s=4, t=240, seed=5):
    r = np.random.default_rng(seed)
    # 2-decimal prices keep the in-jit f32 segment sort aligned with the
    # host float64 sort (distinct keys at f32)
    prices = np.round(r.normal(80, 40, (s, t)), 2).astype(np.float32)
    power = r.uniform(1.0, 3.0, s).astype(np.float32)
    demand = 0.4 * float(power.sum())
    return prices, power, demand


def test_dispatch_window_single_hour_matches_alloc_hour():
    prices, power, demand = _fleet_case()
    s = prices.shape[0]
    avail = power[:, None]
    keys = np.asarray(segment_keys_jnp(prices[:, :1].T, 2.0, 1000.0))
    order, rank = segment_rank_jnp(keys[0])
    prev = np.zeros(s, np.float32)
    dwell = np.zeros(s, np.float32)
    want, _ = dispatch_alloc_hour(prev, dwell, power, order, rank,
                                  demand, min_dwell=3)
    got, _, _ = dispatch_window(prev, dwell, avail, keys,
                                np.full(1, demand, np.float32),
                                min_dwell=3)
    np.testing.assert_array_equal(np.asarray(got)[:, 0],
                                  np.asarray(want))


def test_live_fleet_never_resolve_matches_dispatch_ref():
    """x = 0 and cadence > hours: the live loop never re-solves, every
    site stays always-on, and the committed allocation must be
    bit-identical to the offline sequential oracle."""
    prices, power, demand = _fleet_case()
    s, t = prices.shape
    hours = t
    res = live_fleet_dispatch(
        prices, power, p_on=1e9, p_off=1e9, off_level=0.0,
        idle_frac=0.1, x=0.0, demand=demand, hours=hours, horizon=24,
        cadence=10**6, season=48, migrate_cost=2.0, min_dwell=3)
    order, rank = segment_rank(prices, 2.0)
    want = dispatch_ref(np.broadcast_to(power[:, None], (s, t)),
                        order, rank, np.full(t, demand, np.float32),
                        min_dwell=3)
    np.testing.assert_array_equal(np.asarray(res.alloc_mw),
                                  np.asarray(want))
    assert float(res.shed_mwh) < 1e-3        # f32 fill rounding only
    np.testing.assert_allclose(float(res.delivered_mwh), demand * hours,
                               rtol=1e-6)


def test_live_fleet_resolving_path_is_sane():
    prices, power, demand = _fleet_case(seed=9)
    res = live_fleet_dispatch(
        prices, power, p_on=1e9, p_off=1e9, off_level=0.2,
        idle_frac=0.1, x=0.25, demand=demand, hours=168, horizon=24,
        cadence=1, season=48, migrate_cost=2.0, min_dwell=3)
    assert np.isfinite(float(res.cpc)) and float(res.cpc) > 0
    assert float(res.replan_mw) >= 0.0
    # shutting down the priciest quartile must shed at most the demand
    assert float(res.shed_mwh) <= demand * 168
    # thresholds actually moved off the sentinel
    assert np.all(np.asarray(res.p_off_final) < 1e9)


def test_plan_on_window_matches_scripted_state_machine():
    prices = np.asarray([[50.0, 120.0, 130.0, 40.0, 90.0]], np.float32)
    on0 = np.ones(1, np.float32)
    on_last, cap_w, draw_w = plan_on_window(
        on0, prices, p_on=np.asarray([60.0], np.float32),
        p_off=np.asarray([100.0], np.float32),
        off_level=np.zeros(1, np.float32),
        idle_frac=np.zeros(1, np.float32))
    # 50<=p_on: on; 120>p_off: off; 130>p_off: off; 40<=p_on: on;
    # 90 in the hysteresis band: hold previous (on)
    np.testing.assert_array_equal(np.asarray(cap_w)[0],
                                  [1.0, 0.0, 0.0, 1.0, 1.0])
    assert float(on_last[0]) == 1.0
