"""Regional comparison (paper Section IV-E / Table II / Fig. 7):
where in the world does variable capacity pay?

Runs the model over all ten calibrated regional markets, prints the table
ours-vs-paper, then goes beyond the paper: per-partition plans for a
heterogeneous cluster (§V-C) and the capacity schedule they induce.

  PYTHONPATH=src python examples/regional_study.py
"""

import numpy as np

from repro.core.regions import PAPER_TABLE2, compute_region_row
from repro.energy.markets import generate_market
from repro.energy.presets import region_params
from repro.runtime.elastic import capacity_schedule
from repro.runtime.scheduler import Partition, partition_plans


def main() -> None:
    print(f"{'region':16s} {'p_avg':>7s} {'Psi':>5s} "
          f"{'x_BE% (paper)':>14s} {'x_opt% (paper)':>15s} "
          f"{'CPCred% (paper)':>16s}")
    for region, paper in PAPER_TABLE2.items():
        prices = np.asarray(generate_market(region_params(region)).prices)
        row = compute_region_row(region, prices, psi=paper.psi)

        def fmt(v, pv, w=5):
            a = f"{v:.2f}" if v is not None else "-"
            b = f"{pv:.2f}" if pv is not None else "-"
            return f"{a:>{w}s} ({b:>5s})"

        print(f"{region:16s} {row.p_avg:7.2f} {row.psi:5.2f} "
              f"{fmt(row.x_be_pct, paper.x_be_pct):>14s} "
              f"{fmt(row.x_opt_pct, paper.x_opt_pct):>15s} "
              f"{fmt(row.cpc_red_pct, paper.cpc_red_pct):>16s}")

    # ----- beyond the paper: heterogeneous partitions (§V-C) -------------
    print("\nheterogeneous cluster, Germany market (paper §V-C):")
    prices = np.asarray(generate_market(region_params("germany")).prices)
    partitions = [
        Partition("gpu-2019", power_mw=1.2, fixed_cost_per_hour=60.0),
        Partition("gpu-2023", power_mw=0.8, fixed_cost_per_hour=140.0),
        Partition("cpu-only", power_mw=0.4, fixed_cost_per_hour=30.0),
    ]
    plans = partition_plans(partitions, prices)
    for name, plan in plans.items():
        print(f"  {name:10s} Psi={plan['psi']:.2f} "
              f"viable={plan['viable']} x_opt={plan['x_opt']:.2%} "
              f"CPC red={plan['cpc_reduction']:.2%}")

    cap = capacity_schedule(prices, plans,
                            {p.name: p.power_mw for p in partitions})
    frac_full = float((cap >= 0.999).mean())
    frac_partial = float(((cap > 0.0) & (cap < 0.999)).mean())
    print(f"  capacity schedule: full {frac_full:.1%} of hours, "
          f"partial {frac_partial:.1%}, mean capacity {cap.mean():.1%}")


if __name__ == "__main__":
    main()
