"""Quickstart: the paper's model end-to-end in ~60 lines.

Reproduces the Lichtenberg case study (Section IV-A) on our calibrated
synthetic German market, then asks the question the paper's model answers:
*should this cluster shut down during price spikes, and for how long?*

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.optimizer import optimal_shutdown
from repro.core.price_model import price_variability, resample
from repro.core.regions import PAPER_LICHTENBERG, PSI_LICHTENBERG
from repro.core.tco import shutdowns_viable
from repro.energy.markets import generate_market
from repro.energy.presets import region_params


def main() -> None:
    # 1. a year of hourly prices (calibrated to Germany 2024 statistics)
    market = generate_market(region_params("germany"))
    prices = np.asarray(market.prices)
    print(f"p_avg = {prices.mean():.2f} EUR/MWh "
          f"(paper: 77.84), min {prices.min():.0f}, max {prices.max():.0f}")

    # 2. the system: Lichtenberg's cost distribution (Psi ~ 2)
    psi = PSI_LICHTENBERG

    # 3. the paper's question: is variable capacity worth it?  (Eq. 19)
    pv = price_variability(prices)
    k_small_x = float(np.asarray(pv.k)[10])
    print(f"k at small x: {k_small_x:.2f}; viable iff k > Psi+1 = {psi+1}: "
          f"{bool(shutdowns_viable(psi, k_small_x))}")

    # 4. the full plan: break-even and optimal shutdown fraction
    plan = optimal_shutdown(prices, psi)
    print(f"break-even x  : {float(plan.x_break_even):7.2%} "
          f"(paper {PAPER_LICHTENBERG['x_be_pct']}%)")
    print(f"optimal x     : {float(plan.x_opt):7.2%} "
          f"(paper {PAPER_LICHTENBERG['x_opt_pct']}%)")
    print(f"threshold     : {float(plan.p_thresh):7.2f} EUR/MWh "
          f"(paper {PAPER_LICHTENBERG['p_thresh']})")
    print(f"CPC reduction : {float(plan.cpc_reduction):7.2%} "
          f"(paper {PAPER_LICHTENBERG['cpc_red_pct']}%)")

    # 5. the sampling-interval effect (Fig. 3): weekly shutdowns never pay
    weekly = optimal_shutdown(np.asarray(resample(prices, 24 * 7)), psi)
    print(f"weekly-scale shutdowns viable: {bool(weekly.viable)} "
          "(paper: never)")


if __name__ == "__main__":
    main()
