"""Variable capacity on the inference side: a batched serving engine whose
admission width follows the energy price.

Two engines serve the same request stream over the same simulated market
hours: one always-on, one price-gated (with a 2-slot SLO floor, the §V-B
"keep a subset up for availability" compromise). The comparison shows the
cost-per-token / queue-latency trade-off the paper's model predicts.

  PYTHONPATH=src python examples/price_aware_serving.py
"""

import jax
import numpy as np

from repro.configs.base import get_config
from repro.configs.inputs import reduced_config
from repro.energy.markets import generate_market
from repro.energy.presets import region_params
from repro.models.model import init_params
from repro.energy.stream import PriceStream
from repro.runtime.scheduler import EnergyAwareScheduler, SchedulerConfig
from repro.serving.engine import Request, ServeConfig, ServingEngine


def run(gated: bool, prices, params, cfg, n_requests=120,
        ticks=400) -> dict:
    # the always-on engine still meters the same prices: psi=1e6 makes the
    # plan non-viable, so p_thresh = inf and admission is never gated.
    # Start the replay shortly before the year's worst doldrums so the
    # request stream actually spans a high-price episode.
    start = int(np.argmax(prices)) - 20
    sched = EnergyAwareScheduler(
        PriceStream(prices.copy(), start=max(start, 0)),
        SchedulerConfig(psi=0.8 if gated else 1e6, mode="oracle"))
    eng = ServingEngine(
        params, cfg,
        ServeConfig(slots=4, min_slots=1 if gated else 0, max_seq=64,
                    hours_per_tick=0.5, power_mw=0.5,
                    fixed_cost_per_hour=30.0),
        scheduler=sched)
    rng = np.random.default_rng(7)
    arrivals = np.sort(rng.integers(0, (3 * ticks) // 4, n_requests))
    nxt = 0
    for t in range(ticks):
        while nxt < n_requests and arrivals[nxt] <= t:
            eng.submit(Request(rid=nxt,
                               prompt=rng.integers(
                                   2, cfg.vocab - 1, 8).astype(np.int32),
                               max_new=24))
            nxt += 1
        eng.tick()
    return eng.run(ticks=0)


def main() -> None:
    cfg = reduced_config(get_config("qwen1.5-0.5b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prices = np.asarray(generate_market(
        region_params("south_australia")).prices)

    print("engine        served  EUR/1k-tok  mean-queue-h  energy-cost  x")
    for gated in (False, True):
        out = run(gated, prices, params, cfg)
        name = "price-gated" if gated else "always-on"
        print(f"{name:12s} {out['tokens_served']:7d} "
              f"{out['eur_per_1k_tokens']:11.2f} "
              f"{out['mean_queue_h']:13.2f} "
              f"{out['energy_cost']:12.2f} {out['x_realized']:5.1%}")


if __name__ == "__main__":
    main()
