"""Fleet backtest: a Monte-Carlo market ensemble x systems x policies,
simulated in one jitted call.

The paper evaluates one price trace against one system at a time; the
fleet engine sweeps the whole scenario cube at once. Here: 8 seeds of the
calibrated German market (a Monte-Carlo ensemble giving confidence bands
on the Eq. 19 viability question), 3 systems spanning the paper's Psi
range, and 6 operational policies — thresholds from the PV set,
hysteresis, partial shutdown (paper §V-C via `repro.runtime.elastic`).

  PYTHONPATH=src python examples/fleet_backtest.py
"""

import numpy as np

from repro.core.tco import make_system
from repro.energy.presets import region_params
from repro.fleet import PolicySpec, backtest, build_grid, elastic_policy, \
    summarize


def main() -> None:
    hours = 8760
    markets = [region_params("germany", seed=s) for s in range(8)]
    p_avg = markets[0].p_avg           # generator rescales to this exactly
    systems = [                        # Psi ~ F / (T C p_avg):  0.8 / 2 / 4
        make_system(psi * hours * 1.0 * p_avg, 1.0, float(hours))
        for psi in (0.8, 2.0, 4.0)]
    policies = [
        PolicySpec("always_on"),
        PolicySpec("x1", x=0.01),
        PolicySpec("x3", x=0.03),
        PolicySpec("x3_hyst", x=0.03, hysteresis=0.9,
                   restart_energy_mwh=0.3, restart_time_h=0.25),
        PolicySpec("x8_idle", x=0.08, idle_frac=0.05),
        elastic_policy("x8_half_dp", level=0.5, dp_total=16, x=0.08),
    ]
    grid = build_grid(markets, systems, policies,
                      market_names=[f"de-seed{s}" for s in range(8)],
                      system_names=["psi0.8", "psi2.0", "psi4.0"])
    print(f"grid: {grid.n_markets} markets x {grid.n_systems} systems x "
          f"{grid.n_policies} policies = {grid.n_rows} rows x "
          f"{grid.n_hours} h")

    report = backtest(grid)
    summ = summarize(grid, report)

    print(f"\n{'system':8s} {'best policy (mode)':20s} "
          f"{'CPC red %  mean [min, max]':28s} {'oracle %':>9s} "
          f"{'regret pp':>10s}")
    for m, sname in enumerate(grid.system_names):
        best_k = np.bincount(summ.best_policy[:, m],
                             minlength=grid.n_policies).argmax()
        red = summ.reduction[:, m, best_k] * 100
        oracle = summ.oracle_reduction[:, m].mean() * 100
        regret = summ.regret[:, m, best_k].mean() * 100
        print(f"{sname:8s} {grid.policy_names[best_k]:20s} "
              f"{red.mean():6.2f} [{red.min():5.2f}, {red.max():5.2f}]"
              f"{'':>7s}{oracle:9.2f} {regret:10.2f}")

    # Monte-Carlo confidence on viability: fraction of market draws where
    # the best non-AO policy beats always-on, per system
    print("\nviability across the ensemble (share of market draws with "
          "positive reduction):")
    for m, sname in enumerate(grid.system_names):
        frac = float((summ.best_reduction[:, m] > 1e-4).mean())
        print(f"  {sname:8s} {frac:6.1%}")

    print("\ncross-site dispatch totals per policy (all markets/systems):")
    for k, pname in enumerate(grid.policy_names):
        print(f"  {pname:12s} energy cost {summ.energy_by_policy[k]:14.0f}"
              f"  compute {summ.up_hours_by_policy[k]:12.0f} h")
    print(f"\nfleet TCO {summ.total_cost:.3e}, "
          f"compute {summ.total_up_hours:.3e} h")


if __name__ == "__main__":
    main()
