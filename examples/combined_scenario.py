"""The paper's combined future scenario (Section IV-D), as a narrative:
what happens to the shutdown calculus when volatility rises (Eq. 30,
carbon-tax + cheap renewables) *and* hardware gets 20% cheaper?

  PYTHONPATH=src python examples/combined_scenario.py
"""

import numpy as np

from repro.core.optimizer import optimal_shutdown
from repro.core.scenarios import (amplify_volatility, fossil_share,
                                  scale_fixed_costs)
from repro.energy.markets import generate_market
from repro.energy.presets import region_params


def main() -> None:
    md = generate_market(region_params("germany"))
    prices = np.asarray(md.prices)
    beta = np.asarray(fossil_share(md.fossil, md.renewable))
    amplified = np.asarray(amplify_volatility(prices, beta))

    scenarios = [
        ("historic Germany, Psi=2.0", prices, 2.0),
        ("+ Eq.(30) volatility,  Psi=2.0", amplified, 2.0),
        ("+ 20% cheaper hardware, Psi=1.6", amplified,
         float(scale_fixed_costs(2.0, 0.8))),
    ]
    print("paper IV-D: combined scenario -> x_BE 10.15%, x_opt 2.77%\n")
    print(f"{'scenario':34s} {'x_BE':>7s} {'x_opt':>7s} {'CPC red':>8s} "
          f"{'threshold':>10s}")
    for name, p, psi in scenarios:
        plan = optimal_shutdown(p, psi)
        print(f"{name:34s} {float(plan.x_break_even):7.2%} "
              f"{float(plan.x_opt):7.2%} {float(plan.cpc_reduction):8.2%} "
              f"{float(plan.p_thresh):8.1f}")
    print("\nEach factor alone moves the needle a little; together they "
          "make double-digit\nshutdown fractions viable — the paper's "
          "argument for variable-capacity-ready\nprocurement, quantified.")


if __name__ == "__main__":
    main()
