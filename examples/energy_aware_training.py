"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
under the paper's variable-capacity policy.

Every piece is real: the model (a 12-layer / d=768 dense transformer — the
qwen family scaled to ~100M params), AdamW, the deterministic data
pipeline, checkpointing, and the WS scheduler driving pause/resume against
a calibrated South-Australian price stream (the paper's high-volatility
market). The run reports the realised CPC reduction next to the model's
closed-form prediction — including the shutdown costs the paper's model
deliberately ignores (§V-A), so the gap is the measured bias of the
paper's upper bound.

  PYTHONPATH=src python examples/energy_aware_training.py [--steps 300]
"""

import argparse

import numpy as np

from repro.configs.base import ModelConfig, register
from repro.core.optimizer import optimal_shutdown
from repro.energy.markets import generate_market
from repro.energy.presets import region_params
from repro.energy.stream import PriceStream
from repro.runtime.scheduler import EnergyAwareScheduler, SchedulerConfig
from repro.runtime.trainer import Trainer, TrainerConfig

CFG_100M = register(ModelConfig(
    name="dense-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=2048,
    vocab=32000,
    dtype="float32", param_dtype="float32",   # CPU-friendly
    remat="none",
    attn_q_chunk=128, attn_kv_chunk=256,
))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--psi", type=float, default=0.8)
    ap.add_argument("--region", default="south_australia")
    args = ap.parse_args()

    from repro.launch.roofline import param_counts
    n_params = param_counts(CFG_100M)["total"] \
        + param_counts(CFG_100M)["embed"]
    print(f"model: dense-100m ({n_params/1e6:.0f}M params), "
          f"{args.steps} steps, batch {args.batch} x seq {args.seq}")

    prices = np.asarray(generate_market(
        region_params(args.region)).prices)
    plan = optimal_shutdown(prices, args.psi)
    print(f"plan: x_opt={float(plan.x_opt):.2%} "
          f"threshold={float(plan.p_thresh):.1f} EUR/MWh "
          f"predicted CPC reduction={float(plan.cpc_reduction):.2%}")

    sched = EnergyAwareScheduler(
        PriceStream(prices),
        SchedulerConfig(psi=args.psi, mode="oracle"))
    trainer = Trainer(
        CFG_100M,
        TrainerConfig(steps=args.steps,
                      ckpt_dir="/tmp/repro_e2e_ckpt",
                      ckpt_every=50,
                      hours_per_step=2.0,      # span several market weeks
                      power_mw=1.0,
                      fixed_cost_per_hour=args.psi * prices.mean(),
                      restart_energy_mwh=0.25, restart_time_h=0.1),
        scheduler=sched, batch_size=args.batch, seq_len=args.seq)
    out = trainer.run(log_every=50)

    print("\n=== outcome ===")
    print(f"final loss            : {out['final_loss']:.4f}")
    print(f"uptime                : {out['uptime_hours']:.0f} h "
          f"of {out['hours']:.0f} h (x={out['x_realized']:.2%})")
    print(f"shutdown/resume cycles: {out['restarts']}")
    print(f"realised CPC reduction: {out['cpc_reduction']:.2%} over this "
          f"{out['hours']:.0f}h episode (full-year prediction "
          f"{float(plan.cpc_reduction):.2%}; the model's number is an "
          "upper bound w.r.t. shutdown costs — §V-A — but a short episode "
          "can realise more or less than the year-wide mean)")
    print(f"checkpoint save/restore: {out['ckpt_save_s']*1e3:.0f} ms / "
          f"{out['ckpt_restore_s']*1e3:.0f} ms")


if __name__ == "__main__":
    main()
