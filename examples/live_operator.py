"""Run the live fleet operator over a controller-design sweep and score
every design against the hindsight oracle and the offline-tuned policy.

The offline examples (`fleet_backtest.py`, `tune_policies.py`) assume
the whole price year is known up front. This demo runs the receding-
horizon controller of `repro.live` instead: every simulated hour each
controller forecasts the next H hours from its trailing window,
re-solves its shutdown threshold on its cadence tick, then realizes
costs at the TRUE price — the whole forecaster x horizon x cadence x
family sweep in one jitted scan. The regret table answers the paper's
open operational question: how much of the perfect-foresight saving
survives when you only know prices a day ahead?

``--ensemble`` repeats the sweep on block-bootstrap pseudo-years
(`repro.energy.ensemble`) and reports confidence bands on the regret
gap; ``--retune`` demonstrates the host-level re-tune path — the full
annealed tuner re-entered each tick via
``repro.tune.optimize(warm_start=...)``.

  PYTHONPATH=src python examples/live_operator.py            # full demo
  PYTHONPATH=src python examples/live_operator.py --smoke    # tiny CI run
  PYTHONPATH=src python examples/live_operator.py --smoke --trace out/run
  PYTHONPATH=src python examples/live_operator.py --ensemble
  PYTHONPATH=src python examples/live_operator.py --retune
"""

import argparse
import json
from pathlib import Path

import numpy as np

from repro import obs
from repro.core.tco import make_system
from repro.energy.ensemble import block_bootstrap
from repro.energy.presets import region_params
from repro.fleet import PolicySpec, build_grid
from repro.live import (LiveConfig, build_live_grid, live_backtest,
                        summarize_live)
from repro.obs.profiling import profiled
from repro.tune import TuneConfig, optimize

ARTIFACTS = Path(__file__).resolve().parent.parent / "benchmarks" / \
    "artifacts"


def build(args):
    hours = 400 if args.smoke else 2190
    n_markets = 2 if args.smoke else 4
    markets = [region_params("germany", seed=s).replace(n_hours=hours)
               for s in range(n_markets)]
    p_avg = markets[0].p_avg
    systems = [make_system(2.0 * hours * 1.0 * p_avg, 1.0, float(hours))]
    policies = [PolicySpec("always_on"), PolicySpec("x8", x=0.08),
                PolicySpec("x15", x=0.15)]
    grid = build_grid(markets, systems, policies)
    if args.smoke:
        lgrid = build_live_grid(
            grid, policies, forecasters=("seasonal_naive", "perfect"),
            horizons=(24,), cadences=(1,), families=("quantile", "tuned"))
        cfg = LiveConfig(start=0, hours=336, season=168)
    else:
        lgrid = build_live_grid(
            grid, policies,
            horizons=(24, 168), cadences=(1, 24),
            families=("quantile", "tuned"))
        cfg = LiveConfig(start=0, hours=hours, season=168)
    return grid, lgrid, cfg, policies


def run_sweep(lgrid, cfg):
    with profiled("live.backtest", rows=lgrid.n_rows, hours=cfg.hours):
        res = live_backtest(lgrid, cfg)
    return summarize_live(lgrid, res, cfg)


def ensemble_demo(args, grid, lgrid, cfg, policies) -> dict:
    """Re-run the sweep on block-bootstrap pseudo-years: does the
    forecaster ranking (and the live-vs-oracle gap) survive on price
    paths the controllers never saw?"""
    n_res = 2 if args.smoke else 5
    prices = np.asarray(grid.prices)
    reg_o, reg_f = [], []
    for r in range(n_res):
        resampled = np.stack([
            block_bootstrap(prices[n], 1, block_hours=7 * 24,
                            seed=1000 * r + n)[0]
            for n in range(prices.shape[0])])
        grid_r = build_grid(resampled, [make_system(
            float(grid.fixed[0]), 1.0, float(grid.period[0]))], policies)
        lgrid_r = build_live_grid(
            grid_r, policies, forecasters=lgrid.forecaster_names,
            horizons=lgrid.horizons, cadences=lgrid.cadences,
            families=lgrid.family_names)
        s = run_sweep(lgrid_r, cfg)
        reg_o.append(s.regret_oracle)
        reg_f.append(s.regret_offline)
    reg_o, reg_f = np.stack(reg_o), np.stack(reg_f)   # [R, B]
    mo, so = reg_o.mean(axis=0), reg_o.std(axis=0)
    print(f"\nensemble ({n_res} pseudo-years/market, weekly blocks):")
    print(f"  regret vs oracle:  mean {mo.mean():.2%}  "
          f"band +/- {so.mean():.2%} (per-row std across resamples)")
    print(f"  regret vs offline: mean {reg_f.mean():.2%}  "
          f"band +/- {reg_f.std(axis=0).mean():.2%}")
    return {"resamples": n_res,
            "regret_oracle_mean": float(mo.mean()),
            "regret_oracle_band": float(so.mean()),
            "regret_offline_mean": float(reg_f.mean()),
            "regret_offline_band": float(reg_f.std(axis=0).mean())}


def retune_demo(args) -> int:
    """Host-level receding-horizon re-tuning: re-enter the full annealed
    tuner each tick from the previous tick's solution
    (`optimize(warm_start=...)`) and compare against cold restarts with
    the same step budget — the warm path should never be worse."""
    wlen = 336 if args.smoke else 730
    ticks = 3 if args.smoke else 4
    hours = wlen * ticks
    markets = [region_params("germany", seed=s).replace(n_hours=hours)
               for s in range(2)]
    p_avg = markets[0].p_avg
    policies = [PolicySpec("x8", x=0.08)]
    full = build_grid(markets, [make_system(
        2.0 * hours * 1.0 * p_avg, 1.0, float(hours))], policies)
    prices = np.asarray(full.prices)
    warm_steps = 20 if args.smoke else 60
    cold_steps = warm_steps

    prev = None
    print(f"{'tick':>4} {'window':>14} {'cpc cold':>9} {'cpc warm':>9} "
          f"{'warm gain':>10}")
    gains = []
    for k in range(ticks):
        sl = prices[:, k * wlen:(k + 1) * wlen]
        grid_w = build_grid(sl, [make_system(
            2.0 * wlen * 1.0 * p_avg, 1.0, float(wlen))], policies)
        cold = optimize(grid_w, TuneConfig(steps=cold_steps))
        warm = cold if prev is None else optimize(
            grid_w, TuneConfig(steps=warm_steps), warm_start=prev)
        gain = 1.0 - warm.cpc.mean() / cold.cpc.mean()
        gains.append(gain)
        print(f"{k:>4} {k * wlen:>6}..{(k + 1) * wlen:<6} "
              f"{cold.cpc.mean():>9.3f} {warm.cpc.mean():>9.3f} "
              f"{gain:>10.3%}")
        prev = warm
    ok = all(g >= -1e-2 for g in gains)   # warm never clearly worse
    print(f"\nwarm-started re-tune {'OK' if ok else 'REGRESSED'} over "
          f"{ticks} ticks of {wlen} h")
    return 0 if ok else 1


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep, short window (CI)")
    ap.add_argument("--ensemble", action="store_true",
                    help="repeat the sweep on block-bootstrap "
                    "pseudo-years and report regret confidence bands")
    ap.add_argument("--retune", action="store_true",
                    help="host-level receding-horizon demo: "
                    "optimize(warm_start=...) per tick vs cold restarts")
    ap.add_argument("--trace", metavar="DIR", default=None,
                    help="record a repro.obs telemetry run into DIR "
                    "(trace.jsonl + metrics.json + digest.md) — numeric "
                    "results are bit-identical with or without it")
    args = ap.parse_args()

    if args.trace:
        obs.enable(args.trace, run_id="live_operator")
    try:
        return _main(args)
    finally:
        if args.trace:
            obs.disable()
            from repro.obs.report import render_digest
            digest = render_digest(args.trace)
            Path(args.trace, "digest.md").write_text(digest)
            print(f"telemetry run -> {args.trace} (digest.md, "
                  "trace.jsonl, metrics.json)")


def _main(args) -> int:
    if args.retune:
        return retune_demo(args)

    grid, lgrid, cfg, policies = build(args)
    print(f"live sweep: {lgrid.n_rows} controllers "
          f"({grid.n_markets} markets x {grid.n_policies} policies x "
          f"{len(lgrid.forecaster_names)} forecasters x "
          f"{len(lgrid.horizons)} horizons x {len(lgrid.cadences)} "
          f"cadences x {len(lgrid.family_names)} families) "
          f"over {cfg.hours} h")
    summary = run_sweep(lgrid, cfg)
    print()
    print(summary.render_table())

    sandwich = bool(np.all(
        summary.cpc_oracle <= summary.cpc_live * (1 + 1e-5) + 1e-6))
    best = summary.table[0]
    print(f"\nbest design: {best['forecaster']} H={best['horizon']} "
          f"cadence={best['cadence']} {best['family']} — regret "
          f"{best['regret_oracle']:.2%} vs oracle, "
          f"{best['regret_offline']:+.2%} vs offline-tuned")
    print(f"hindsight-oracle lower bound holds on all rows: {sandwich}")

    out = {
        "rows": lgrid.n_rows, "hours": cfg.hours,
        "cpc_live_mean": float(summary.cpc_live.mean()),
        "regret_oracle_mean": float(summary.regret_oracle.mean()),
        "regret_offline_mean": float(summary.regret_offline.mean()),
        "best": best, "sandwich_holds": sandwich,
        "table": list(summary.table),
    }
    if args.ensemble:
        out["ensemble"] = ensemble_demo(args, grid, lgrid, cfg, policies)
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    name = "live_smoke" if args.smoke else "live_operator"
    (ARTIFACTS / f"{name}.json").write_text(json.dumps(out, indent=1))
    print(f"artifact -> {ARTIFACTS / f'{name}.json'}")
    if not sandwich:
        print("ERROR: a live controller beat the hindsight oracle — "
              "bound violated")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
