"""Gradient-tune shutdown policies over a fleet grid, then validate the
tuned thresholds on held-out bootstrap resamples of each market.

The swept policies of `examples/fleet_backtest.py` only find the best
point *on the grid*; `repro.tune.optimize` relaxes the hysteresis state
machine with annealed sigmoid gates and descends each row's CPC by
Adam — all rows in one jitted loop — then re-evaluates hard (tau -> 0).
Validation: `repro.energy.ensemble.block_bootstrap` resamples each
market's trace into held-out pseudo-years; a tuned policy that only
exploited one spike's placement loses its edge there, one that captures
the market's structure keeps it.

With ``--dispatch-soft`` the demo instead contrasts dispatch-aware
tuning (gradients through the relaxed water-fill dispatcher,
``coupling=Coupling(dispatch=...)``) against the re-score-only path
(``coupling=Coupling(reeval=...)``): both are hard-scored on feasible
`repro.dispatch.dispatch`, and the per-site threshold table shows the
swing-site effect — a site the fleet keeps as always-on backup learns a
threshold far from its isolated optimum.

  PYTHONPATH=src python examples/tune_policies.py           # full demo
  PYTHONPATH=src python examples/tune_policies.py --smoke   # tiny CI run
  PYTHONPATH=src python examples/tune_policies.py --dispatch-soft
  PYTHONPATH=src python examples/tune_policies.py --smoke --trace out/run
"""

import argparse
import json
from pathlib import Path

import numpy as np

from repro import obs
from repro.obs.profiling import profiled
from repro.core.tco import make_system
from repro.dispatch import DispatchConfig
from repro.energy.ensemble import block_bootstrap
from repro.energy.presets import region_params
from repro.fleet import PolicySpec, build_grid
from repro.tune import (Coupling, TuneConfig, cell_best_rows, hard_cpc,
                        optimize,
                        problem_from_grid)

ARTIFACTS = Path(__file__).resolve().parent.parent / "benchmarks" / \
    "artifacts"


def build(args):
    hours = 400 if args.smoke else 4380
    n_markets = 2 if args.smoke else 4
    markets = [region_params("germany", seed=s) for s in range(n_markets)]
    markets = [mp.replace(n_hours=hours) for mp in markets]
    p_avg = markets[0].p_avg
    psis = (2.0,) if args.smoke else (0.8, 2.0)
    systems = [make_system(psi * hours * 1.0 * p_avg, 1.0, float(hours))
               for psi in psis]
    policies = [PolicySpec("always_on"), PolicySpec("x3", x=0.03),
                PolicySpec("x8", x=0.08)]
    if not args.smoke:
        policies += [PolicySpec("x1", x=0.01), PolicySpec("x15", x=0.15),
                     PolicySpec("x5_hyst", x=0.05, hysteresis=0.9)]
    grid = build_grid(markets, systems, policies,
                      system_names=[f"psi{p}" for p in psis])
    return grid


def validate_on_resamples(grid, res, n_resamples: int, seed: int = 123):
    """Held-out check: hard CPC of tuned vs *cell-best* swept params on
    block-bootstrap resamples of each market's trace.

    The baseline per row is the best swept policy of its (market,
    system) cell — judged on the training trace, then deployed on the
    resample — so the comparison is the one an operator faces: tuned
    thresholds vs the best hand-picked policy, both on unseen data."""
    prices = np.asarray(grid.prices)
    problem = problem_from_grid(grid)
    best_row = cell_best_rows(grid, res.cpc_swept)
    deltas = []
    for r in range(n_resamples):
        resampled = np.stack([
            block_bootstrap(prices[n], 1, block_hours=7 * 24,
                            seed=seed + 1000 * r + n)[0]
            for n in range(prices.shape[0])])
        prob_r = problem._replace(
            prices=resampled,
            price_sum=resampled.sum(axis=1)[np.asarray(grid.market_idx)])
        cpc_tuned = np.asarray(hard_cpc(
            res.params.p_on, res.params.p_off, res.params.off_level,
            prob_r), np.float64)
        cpc_swept = np.asarray(hard_cpc(
            grid.p_on[best_row], grid.p_off[best_row],
            grid.off_level[best_row], prob_r), np.float64)
        deltas.append(1.0 - cpc_tuned / cpc_swept)
    return np.stack(deltas)                       # [R, B]


def dispatch_soft_demo(args) -> int:
    """Dispatch-aware vs re-score-only on a one-policy-per-site fleet:
    the quantitative setting (soft selection is exact at K = 1), small
    enough to run in about a minute on CPU."""
    hours = 400 if args.smoke else 2190
    n_sites = 4
    markets = [region_params("germany", seed=s).replace(n_hours=hours)
               for s in range(n_sites)]
    p_avg = markets[0].p_avg
    systems = [make_system(0.5 * hours * 1.0 * p_avg, 1.0, float(hours))]
    grid = build_grid(markets, systems,
                      [PolicySpec("x8", x=0.08, off_level=0.3)],
                      market_names=[f"de-seed{s}" for s in range(n_sites)])
    dcfg = DispatchConfig(demand_frac=0.25, migrate_cost=4.0,
                          min_dwell_h=3)
    steps = 40 if args.smoke else 200
    print(f"fleet: {n_sites} sites x {grid.n_hours} h, demand "
          f"{dcfg.demand_frac:.0%} of ratings, fee {dcfg.migrate_cost}, "
          f"dwell {dcfg.min_dwell_h} h; {steps} steps")

    rescore = optimize(grid, TuneConfig(steps=steps,
                                        coupling=Coupling(reeval=dcfg)))
    aware = optimize(grid, TuneConfig(steps=steps,
                                      coupling=Coupling(dispatch=dcfg)))
    dr, da = rescore.dispatch, aware.dispatch
    cpc_r = min(dr["cpc_tuned"], dr["cpc_swept"])
    cpc_a = min(da["cpc_tuned"], da["cpc_swept"])

    print(f"\n{'site':10s} {'isolated p_off':>14s} {'aware p_off':>12s} "
          f"{'share iso':>10s} {'share aware':>12s}")
    chosen_r = dr[dr["chosen"]] if dr["chosen"] else None
    chosen_a = da[da["chosen"]] if da["chosen"] else None
    share_r = chosen_r.site_mwh / chosen_r.delivered_mwh \
        if chosen_r is not None else np.full(n_sites, np.nan)
    share_a = chosen_a.site_mwh / chosen_a.delivered_mwh \
        if chosen_a is not None else np.full(n_sites, np.nan)
    for i, name in enumerate(grid.market_names):
        print(f"{name:10s} {float(rescore.params.p_off[i]):14.1f} "
              f"{float(aware.params.p_off[i]):12.1f} "
              f"{share_r[i]:10.1%} {share_a[i]:12.1%}")
    print(f"\nfleet CPC under hard feasible dispatch: re-score-only "
          f"{cpc_r:.3f} ({dr['chosen']}) vs dispatch-aware {cpc_a:.3f} "
          f"({da['chosen']})")
    edge = 1.0 - cpc_a / cpc_r if np.isfinite(cpc_r) else float("nan")
    print(f"dispatch-aware edge: {edge:.3%}")
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    (ARTIFACTS / "tune_dispatch_soft.json").write_text(json.dumps({
        "hours": hours, "sites": n_sites, "steps": steps,
        "cpc_rescore": cpc_r, "cpc_aware": cpc_a, "edge": edge,
        "p_off_rescore": np.asarray(rescore.params.p_off).tolist(),
        "p_off_aware": np.asarray(aware.params.p_off).tolist(),
    }, indent=1))
    return 0 if cpc_a <= cpc_r * (1.0 + 1e-9) else 1


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid, few steps (CI)")
    ap.add_argument("--resamples", type=int, default=None)
    ap.add_argument("--fused", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="checkpointed custom-VJP soft scan (default); "
                    "--no-fused uses native autodiff through the "
                    "associative scan (the PR-3 baseline)")
    ap.add_argument("--dispatch-soft", action="store_true",
                    help="dispatch-aware tuning demo: gradients through "
                    "the relaxed water-fill vs re-score-only, with the "
                    "swing-site threshold table")
    ap.add_argument("--trace", metavar="DIR", default=None,
                    help="record a repro.obs telemetry run into DIR "
                    "(trace.jsonl + metrics.json + digest.md) — numeric "
                    "results are bit-identical with or without it")
    args = ap.parse_args()

    if args.trace:
        obs.enable(args.trace, run_id="tune_policies")
    try:
        return _main(args)
    finally:
        if args.trace:
            obs.disable()
            from repro.obs.report import render_digest
            digest = render_digest(args.trace)
            Path(args.trace, "digest.md").write_text(digest)
            print(f"telemetry run -> {args.trace} (digest.md, "
                  "trace.jsonl, metrics.json)")


def _main(args) -> int:
    if args.dispatch_soft:
        return dispatch_soft_demo(args)

    grid = build(args)
    cfg = TuneConfig(steps=40 if args.smoke else 300, fused=args.fused)
    print(f"grid: {grid.n_markets} markets x {grid.n_systems} systems x "
          f"{grid.n_policies} policies = {grid.n_rows} rows x "
          f"{grid.n_hours} h; tuning {cfg.steps} steps, "
          f"tau {cfg.tau_start} -> {cfg.tau_end}, "
          f"{'fused' if cfg.fused else 'native'} VJP")

    with profiled("tune.optimize", rows=grid.n_rows, steps=cfg.steps):
        res = optimize(grid, cfg)
    print(f"soft loss {res.history['loss'][0]:.4f} -> "
          f"{res.history['loss'][-1]:.4f}")
    print(f"improvement vs best swept policy per row: "
          f"mean {res.improvement_vs_best.mean():.3%} "
          f"max {res.improvement_vs_best.max():.3%}  "
          f"(strictly better on "
          f"{(res.cpc < res.cpc_swept_best * (1 - 1e-6)).sum()}"
          f"/{grid.n_rows} rows)")
    print(f"improvement vs each row's own swept policy: "
          f"mean {res.improvement_vs_own.mean():.3%}")

    n_res = args.resamples or (3 if args.smoke else 8)
    deltas = validate_on_resamples(grid, res, n_res)   # [R, B]
    held = deltas.mean(axis=0)
    print(f"\nheld-out ({n_res} block-bootstrap resamples/market): tuned "
          f"vs cell-best swept params on unseen pseudo-years:")
    print(f"  mean improvement {held.mean():.3%}  "
          f"rows improved {(held > 0).mean():.1%}")

    ok = bool(np.all(res.cpc <= res.cpc_swept_best * (1 + 1e-6)))
    out = {
        "rows": grid.n_rows,
        "hours": grid.n_hours,
        "steps": cfg.steps,
        "loss_first": float(res.history["loss"][0]),
        "loss_last": float(res.history["loss"][-1]),
        "improvement_vs_best_mean": float(res.improvement_vs_best.mean()),
        "improvement_vs_own_mean": float(res.improvement_vs_own.mean()),
        "rows_strictly_better": int(
            (res.cpc < res.cpc_swept_best * (1 - 1e-6)).sum()),
        "held_out_resamples": n_res,
        "held_out_improvement_mean": float(held.mean()),
        "guarantee_holds": ok,
    }
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    name = "tune_smoke" if args.smoke else "tune_policies"
    (ARTIFACTS / f"{name}.json").write_text(json.dumps(out, indent=1))
    print(f"\nartifact -> {ARTIFACTS / f'{name}.json'}")
    if not ok:
        print("ERROR: tuned CPC worse than best swept policy on some row")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
