"""Chaos harness: run the fleet through a seeded fault storm and check
it degrades instead of crashing.

A `repro.faults.FaultTrace` — site outages, NaN price-feed gaps,
forecast blackouts, demand surges, all compiled to [S, T]/[N, T] masks
that flow *in-scan* through the engines — is injected into the three
operating layers and each is compared against its fault-free twin:

  1. the fleet backtest (`repro.faults.faulted_backtest`): stale-price
     decisions, forced outage state, true-price settlement;
  2. cross-site dispatch (`repro.faults.faulted_problem` +
     `repro.dispatch.Relief`): storm-induced infeasible hours shed at
     VoLL instead of raising `DispatchInfeasible`;
  3. the live operator (`repro.live.live_backtest(faults=...)`): the
     forecast fallback ladder (fresh -> age-shifted last-published ->
     seasonal-naive -> persistence) under blackouts, outage-aware
     state carry with restarts billed on recovery.

The run PASSES when every layer returns finite results and the CPC
degradation stays inside a sanity bound (a storm should cost percent,
not orders of magnitude). With ``--trace`` the telemetry digest gains
a Degradation section with per-fault shed/fallback counts.

  PYTHONPATH=src python examples/chaos_fleet.py              # full storm
  PYTHONPATH=src python examples/chaos_fleet.py --smoke      # tiny CI run
  PYTHONPATH=src python examples/chaos_fleet.py --smoke --trace out/run
  PYTHONPATH=src python examples/chaos_fleet.py --seed 11
"""

import argparse
from pathlib import Path

import numpy as np

from repro import obs
from repro.core.tco import make_system
from repro.dispatch import DispatchConfig, Relief, dispatch
from repro.energy.markets import MarketParams
from repro.faults import (FaultTrace, faulted_backtest, faulted_problem,
                          random_storm)
from repro.fleet import PolicySpec, backtest, build_grid, summarize
from repro.live import LiveConfig, build_live_grid, live_backtest

# a storm should cost percent, not orders of magnitude: fail the run if
# mean CPC degrades by more than this factor
MAX_CPC_DEGRADATION = 0.5


def build(args):
    hours = 400 if args.smoke else 2190
    n_markets = 2 if args.smoke else 4
    markets = [MarketParams(n_hours=hours, seed=s)
               for s in range(n_markets)]
    systems = [make_system(0.8 * hours * 1.0 * 80.0, 1.0, float(hours))]
    policies = [PolicySpec("always_on"),
                PolicySpec("x10", x=0.10, off_level=0.3),
                PolicySpec("x25", x=0.25, off_level=0.3)]
    return build_grid(markets, systems, policies), policies, hours


def storm_for(args, grid, hours) -> FaultTrace:
    n = 1 if args.smoke else 3
    return random_storm(args.seed, grid.n_rows, grid.n_markets, hours,
                        n_outages=2 * n, n_price_gaps=2 * n,
                        n_blackouts=n, n_surges=n,
                        max_duration=max(24, hours // 12))


def chaos_backtest(grid, storm) -> tuple:
    ref = backtest(grid, use_pallas=False)
    hit = faulted_backtest(grid, storm)
    base, got = (float(np.mean(np.asarray(r.cpc))) for r in (ref, hit))
    print(f"backtest   mean CPC {base:9.3f} -> {got:9.3f} "
          f"({got / base - 1.0:+.2%})")
    return base, got


def chaos_dispatch(grid, args, hours) -> tuple:
    cfg = DispatchConfig(demand_frac=0.3, migrate_cost=2.0)
    summary = summarize(grid, backtest(grid, use_pallas=False),
                        dispatch_cfg=cfg)
    prob = _site_problem(grid, summary, cfg)
    n_sites = np.asarray(prob.avail_mw).shape[0]
    # outage targets index dispatch *sites* here, so the dispatch layer
    # gets its own storm drawn at the site count
    storm = random_storm(args.seed, n_sites, grid.n_markets, hours,
                         max_duration=max(24, hours // 12))
    fp = faulted_problem(
        prob, storm.compile(n_sites, grid.n_markets, hours),
        site_market_idx=np.asarray(grid.market_idx)[summary.dispatch_rows])
    res = dispatch(fp._replace(relief=Relief(voll_eur_mwh=3000.0)))
    base = float(summary.dispatch.cpc)
    print(f"dispatch   CPC {base:9.3f} -> {float(res.cpc):9.3f} "
          f"(shed {res.shed_mwh:.2f} MWh over {res.n_shed_hours} h "
          f"at VoLL)")
    return base, float(res.cpc)


def _site_problem(grid, summary, cfg):
    from repro.dispatch import build_problem
    rows = summary.dispatch_rows
    markets = np.asarray(grid.market_idx)[rows]
    return build_problem(
        np.asarray(grid.prices)[markets],
        np.asarray(grid.p_on)[rows], np.asarray(grid.p_off)[rows],
        np.asarray(grid.off_level)[rows], np.asarray(grid.power)[rows],
        cfg, fixed=np.asarray(grid.fixed)[rows])


def chaos_live(grid, policies, args, hours) -> tuple:
    lgrid = build_live_grid(
        grid, policies, forecasters=("seasonal_naive", "persistence"),
        horizons=(24,), cadences=(1,), families=("quantile",))
    # smoke keeps the window short; the full run covers the whole trace
    # tail so every storm event lands inside the live window
    live_h = min(336, hours - 168) if hours <= 400 else hours - 168
    cfg = LiveConfig(start=168, hours=live_h, season=168)
    live_storm = random_storm(args.seed, lgrid.n_rows, grid.n_markets,
                              hours, max_duration=max(24, hours // 12))
    ref = live_backtest(lgrid, cfg)
    hit = live_backtest(lgrid, cfg, faults=live_storm)
    base, got = (float(np.mean(np.asarray(r.cpc))) for r in (ref, hit))
    print(f"live       mean CPC {base:9.3f} -> {got:9.3f} "
          f"({got / base - 1.0:+.2%})")
    return base, got


def _main(args) -> int:
    grid, policies, hours = build(args)
    storm = storm_for(args, grid, hours)
    print(f"chaos storm (seed {args.seed}): {len(storm)} faults over "
          f"{grid.n_rows} rows x {grid.n_markets} markets x {hours} h")
    for ev in storm.events:
        print(f"  - {ev.kind:>18} target={ev.target:<3} "
              f"hours {ev.start}..{ev.start + ev.duration} "
              f"magnitude={ev.magnitude:g}")
    print()

    pairs = [chaos_backtest(grid, storm),
             chaos_dispatch(grid, args, hours),
             chaos_live(grid, policies, args, hours)]

    worst = max(got / base - 1.0 for base, got in pairs)
    finite = all(np.isfinite(got) for _, got in pairs)
    ok = finite and worst <= MAX_CPC_DEGRADATION
    print(f"\nworst CPC degradation: {worst:+.2%} "
          f"(bound {MAX_CPC_DEGRADATION:.0%}) -> "
          f"{'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny storm, short traces (CI)")
    ap.add_argument("--seed", type=int, default=7,
                    help="storm seed (default 7)")
    ap.add_argument("--trace", metavar="DIR", default=None,
                    help="record a repro.obs telemetry run into DIR "
                    "(trace.jsonl + digest.md with a Degradation "
                    "section) — numeric results are bit-identical "
                    "with or without it")
    args = ap.parse_args()

    if args.trace:
        obs.enable(args.trace, run_id="chaos_fleet")
    try:
        return _main(args)
    finally:
        if args.trace:
            obs.disable()
            from repro.obs.report import render_digest
            Path(args.trace, "digest.md").write_text(
                render_digest(args.trace))
            print(f"telemetry run -> {args.trace} (digest.md, "
                  "trace.jsonl, metrics.json)")


if __name__ == "__main__":
    raise SystemExit(main())
