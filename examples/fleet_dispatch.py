"""Feasible cross-site dispatch over a multi-market fleet.

`examples/fleet_backtest.py` answers "what does each site's policy cost
in isolation?"; this example answers the operator's next question: with
sites in several markets, where should the fleet's *load* actually run
each hour? The dispatcher (`src/repro/dispatch/`) allocates a fleet-wide
compute demand across the best-policy site schedules under hard
constraints — per-site capacity, a total power cap, an aggregate compute
floor — charging every cross-site move a migration fee and locking
newly placed load for a minimum dwell.

The sweep below shows the thrash/price trade-off: free migration chases
the hourly argmin price (cheapest possible energy, constant movement),
while fees and dwell locks cut the move count by orders of magnitude for
a small energy premium. The final section swaps the constant demand for
a diurnal [T] profile (`repro.dispatch.diurnal_demand`) — load peaking
in the evening, bottoming out at night — which the dispatcher follows
hour by hour (ramps are demand changes, not billed migrations).

  PYTHONPATH=src python examples/fleet_dispatch.py
  PYTHONPATH=src python examples/fleet_dispatch.py --trace out/dispatch
"""

import argparse
from pathlib import Path

import numpy as np

from repro import obs
from repro.core.tco import make_system
from repro.dispatch import DispatchConfig, diurnal_demand
from repro.energy.presets import region_params
from repro.fleet import PolicySpec, backtest, build_grid, elastic_policy, \
    summarize


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", metavar="DIR", default=None,
                    help="record a repro.obs telemetry run into DIR "
                    "(trace.jsonl + metrics.json + digest.md)")
    args = ap.parse_args()
    if args.trace:
        obs.enable(args.trace, run_id="fleet_dispatch")
    try:
        _main()
    finally:
        if args.trace:
            obs.disable()
            from repro.obs.report import render_digest
            Path(args.trace, "digest.md").write_text(
                render_digest(args.trace))
            print(f"\ntelemetry run -> {args.trace} (digest.md, "
                  "trace.jsonl, metrics.json)")


def _main() -> None:
    hours = 8760
    n_markets = 8
    markets = [region_params("germany", seed=s) for s in range(n_markets)]
    p_avg = markets[0].p_avg           # generator rescales to this exactly
    systems = [make_system(2.0 * hours * 1.0 * p_avg, 1.0, float(hours))]
    policies = [
        PolicySpec("always_on"),
        PolicySpec("x5_part", x=0.05, off_level=0.25),
        PolicySpec("x10_part", x=0.10, off_level=0.25, hysteresis=0.9),
        elastic_policy("x10_half_dp", level=0.5, dp_total=16, x=0.10),
    ]
    grid = build_grid(markets, systems, policies,
                      market_names=[f"de-seed{s}" for s in range(n_markets)],
                      system_names=["psi2.0"])
    report = backtest(grid)
    print(f"fleet: {grid.n_markets} sites x {grid.n_policies} candidate "
          f"policies x {grid.n_hours} h")

    print(f"\n{'migrate fee':>12s} {'dwell':>6s} {'fleet CPC':>10s} "
          f"{'energy':>12s} {'migration':>10s} {'moves':>6s} "
          f"{'cap slack MW':>13s}")
    for fee, dwell in ((0.0, 0), (2.0, 0), (5.0, 4), (20.0, 24)):
        cfg = DispatchConfig(demand_frac=0.35, migrate_cost=fee,
                             min_dwell_h=dwell)
        summ = summarize(grid, report, dispatch_cfg=cfg)
        d = summ.dispatch
        print(f"{fee:12.1f} {dwell:6d} {d.cpc:10.2f} "
              f"{d.energy_cost:12.0f} {d.migration_cost:10.0f} "
              f"{d.n_migrations:6d} {d.slack_capacity_mw:13.2f}")

    # where did the compute actually run?
    cfg = DispatchConfig(demand_frac=0.35, migrate_cost=5.0, min_dwell_h=4)
    summ = summarize(grid, report, dispatch_cfg=cfg)
    d = summ.dispatch
    share = d.site_mwh / d.delivered_mwh
    best = [grid.policy_names[k] for k in summ.best_policy[:, 0]]
    print(f"\nsite shares of {d.delivered_mwh:.0f} MWh delivered "
          f"(fee 5, dwell 4):")
    for name, pol, s in zip(grid.market_names, best, share):
        print(f"  {name:10s} ({pol:12s}) {s:6.1%}")
    print(f"\nfloor slack {d.slack_floor_mwh:.0f} MWh, "
          f"power slack {d.slack_power_mw:.1f} MW")

    # diurnal demand profile: same fleet, load that breathes with the
    # day instead of a constant draw
    n_mw = float(np.asarray(grid.power)[::grid.n_policies].sum())
    prof = diurnal_demand(hours, base_mw=0.35 * n_mw,
                          swing_mw=0.15 * n_mw, peak_hour=18.0)
    cfg_d = DispatchConfig(demand_mw=prof, migrate_cost=5.0,
                           min_dwell_h=4)
    dd = summarize(grid, report, dispatch_cfg=cfg_d).dispatch
    profile = np.asarray(prof)
    print(f"\ndiurnal demand {profile.min():.1f}-{profile.max():.1f} MW "
          f"(peak 18:00): fleet CPC {dd.cpc:.2f} "
          f"(constant-demand CPC {d.cpc:.2f}), {dd.n_migrations} moves, "
          f"cap slack {dd.slack_capacity_mw:.2f} MW")
    night = profile.argmin() % 24
    print(f"delivered follows the profile exactly: hour-{night:02d} "
          f"trough {dd.alloc_mw.sum(axis=0)[profile.argmin()]:.2f} MW vs "
          f"peak {dd.alloc_mw.sum(axis=0)[profile.argmax()]:.2f} MW")


if __name__ == "__main__":
    main()
