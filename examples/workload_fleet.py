"""Workload-coupled demand: serve a stochastic request trace through
the fleet and price shutdowns by what they do to users.

A `repro.workload.Workload` turns the exogenous-demand backtest into a
closed loop: a seeded doubly-stochastic Poisson arrival process
(diurnal base rate x bursty Gamma overdispersion) is converted to MW
through per-model serving throughput, and every scenario row serves
all demand draws hour by hour with its *realised* capacity. Unserved
work defers into a bounded, deadline-aged queue (priced at the SLO
penalty per MWh-hour) or drops (priced at the `repro.dispatch.Relief`
VoLL rate), so the CPC of a shutdown policy becomes a *distribution*
over demand draws instead of a point value.

The run walks the full loop:

  1. coupled backtest (`repro.workload.workload_backtest`): CPC
     p10/p50/p90 over the draws per policy, served/deferred/dropped;
  2. SLO-aware tuning (`repro.tune.optimize` with
     ``TuneConfig(workload=...)``): thresholds learned under the soft
     work-ledger term, selected by realized workload cost — never
     worse than the best swept policy under the same workload;
  3. live operation (`repro.live.live_fleet_dispatch(workload=...)`)
     with a demand-surge fault hitting the arrival process itself.

  PYTHONPATH=src python examples/workload_fleet.py            # full run
  PYTHONPATH=src python examples/workload_fleet.py --smoke    # tiny CI run
  PYTHONPATH=src python examples/workload_fleet.py --smoke --trace out/run
"""

import argparse
from pathlib import Path

import numpy as np

from repro import obs
from repro.core.tco import make_system
from repro.energy.markets import MarketParams
from repro.faults import FaultEvent, FaultTrace
from repro.fleet import PolicySpec, build_grid, summarize
from repro.live import live_fleet_dispatch
from repro.tune import TuneConfig, optimize
from repro.workload import Workload, workload_backtest


def build(args):
    hours = 400 if args.smoke else 2190
    n_markets = 2 if args.smoke else 4
    markets = [MarketParams(n_hours=hours, seed=s)
               for s in range(n_markets)]
    systems = [make_system(0.8 * hours * 1.0 * 80.0, 1.0, float(hours))]
    policies = [PolicySpec("always_on"),
                PolicySpec("x10", x=0.10, off_level=0.3),
                PolicySpec("x25", x=0.25, off_level=0.3),
                PolicySpec("x40", x=0.40, off_level=0.3)]
    workload = Workload(n_draws=8 if args.smoke else 32, seed=args.seed)
    grid = build_grid(markets, systems, policies, workload=workload)
    return grid, policies, workload, hours


def coupled_backtest(grid, workload):
    res = workload_backtest(grid).workload
    names = grid.policy_names
    k = len(names)
    print(f"coupled backtest: {grid.n_rows} rows x {grid.n_hours} h x "
          f"{res.n_draws} demand draws")
    print(f"{'policy':>10} {'cpc p10':>9} {'cpc p50':>9} {'cpc p90':>9} "
          f"{'served':>8} {'dropped':>8}")
    for p in range(k):
        rows = np.asarray(grid.policy_idx) == p
        print(f"{names[p]:>10} "
              f"{np.mean(np.asarray(res.cpc_p10)[rows]):9.2f} "
              f"{np.mean(np.asarray(res.cpc_p50)[rows]):9.2f} "
              f"{np.mean(np.asarray(res.cpc_p90)[rows]):9.2f} "
              f"{np.mean(np.asarray(res.served_mwh)[rows]):8.1f} "
              f"{np.mean(np.asarray(res.dropped_mwh)[rows]):8.2f}")
    # the summary view carries the same result
    summary = summarize(grid, workload_backtest(grid).report)
    assert summary.workload is not None
    return res


def slo_tuning(grid, workload, args):
    steps = 40 if args.smoke else 200
    res = optimize(grid, TuneConfig(steps=steps, workload=workload))
    ok = bool(np.all(np.isfinite(res.workload_cost)))
    print(f"\nSLO-aware tuning ({steps} steps): mean realized workload "
          f"cost {np.mean(res.workload_cost):.0f} EUR "
          f"(sources tuned={int(np.sum(res.source == 0))} "
          f"own={int(np.sum(res.source == 1))} "
          f"cell-best={int(np.sum(res.source == 2))})")
    return res, ok


def live_surge(grid, workload, hours, args):
    start = hours // 2
    live_h = min(96, hours - start)
    surge = FaultTrace(events=(
        FaultEvent("demand_surge", 0, start + live_h // 4,
                   max(6, live_h // 8), 3.0),), seed=args.seed)
    sites = min(3, grid.n_markets)
    prices = np.asarray(grid.prices)[:sites]
    base = live_fleet_dispatch(
        prices, 1.0, 30.0, 60.0, 0.0, 0.0, np.full(sites, 0.25),
        start=start, hours=live_h, workload=workload)
    hit = live_fleet_dispatch(
        prices, 1.0, 30.0, 60.0, 0.0, 0.0, np.full(sites, 0.25),
        start=start, hours=live_h, workload=workload, faults=surge)
    print(f"\nlive ({sites} sites, {live_h} h): CPC p50 "
          f"{base.workload['cpc_p50']:.2f} -> {hit.workload['cpc_p50']:.2f} "
          "under a 3x demand surge "
          f"(dropped {np.mean(base.workload['dropped_mwh']):.2f} -> "
          f"{np.mean(hit.workload['dropped_mwh']):.2f} MWh)")
    return base, hit


def _main(args) -> int:
    grid, policies, workload, hours = build(args)
    print(f"workload: base {workload.base_rps:g} req/s, "
          f"{workload.tokens_per_request:g} tok/req -> "
          f"{workload.mw_per_request_hour * workload.base_rps * 3600.0:.3f}"
          f" MW mean demand at base rate; deadline {workload.deadline_h} h,"
          f" queue bound {workload.queue_bound_mwh:g} MWh\n")

    res = coupled_backtest(grid, workload)
    tuned, tune_ok = slo_tuning(grid, workload, args)
    base, hit = live_surge(grid, workload, hours, args)

    finite = (bool(np.all(np.isfinite(np.asarray(res.cpc_p50))))
              and tune_ok
              and np.isfinite(hit.workload["cpc_p50"]))
    surged = (np.mean(hit.workload["dropped_mwh"])
              >= np.mean(base.workload["dropped_mwh"]))
    ok = finite and surged
    print(f"\n{'PASS' if ok else 'FAIL'} (finite={finite}, "
          f"surge increased drops={surged})")
    return 0 if ok else 1


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace, few draws (CI)")
    ap.add_argument("--seed", type=int, default=0,
                    help="workload / surge seed (default 0)")
    ap.add_argument("--trace", metavar="DIR", default=None,
                    help="record a repro.obs telemetry run into DIR "
                    "(trace.jsonl + digest.md with a Workload section) "
                    "— numeric results are bit-identical with or "
                    "without it")
    args = ap.parse_args()

    if args.trace:
        obs.enable(args.trace, run_id="workload_fleet")
    try:
        return _main(args)
    finally:
        if args.trace:
            obs.disable()
            from repro.obs.report import render_digest
            Path(args.trace, "digest.md").write_text(
                render_digest(args.trace))
            print(f"telemetry run -> {args.trace} (digest.md, "
                  "trace.jsonl, metrics.json)")


if __name__ == "__main__":
    raise SystemExit(main())
